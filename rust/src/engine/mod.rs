//! The decode engine: drives the per-stage HLO programs through the PJRT
//! runtime with LycheeCluster retrieval between QKV and attention.
//!
//! One decode step for a batch of sequences (Algorithm 1, decode phase):
//!
//! ```text
//! embed(tokens)                                       [B, D]
//! for layer l in 0..L:
//!     q,k,v = qkv(x, weights_l, positions)            [B, H, Dh]
//!     cache.append(l, k, v)
//!     active = policy_l.select(q)  ∪  {self}          (L3 retrieval)
//!     K,V,mask = cache.gather(l, active, bucket)      [B, M, H, Dh]
//!     a = attn(q, K, V, mask)           <- Pallas kernel artifact
//!     x = proj_ffn(a, x, weights_l)
//! logits = lm_head(x)
//! ```
//!
//! Weights are uploaded to device once at engine construction (cached
//! literals) — per-step uploads are only the gathered active set, the
//! tiny stage activations, and the masks.

pub mod sim;

use crate::config::Config;
use crate::index::reps::KeySource;
use crate::kvcache::{KvCache, PagePool, PrefixCache, PAGE_SIZE};
use crate::model::{Manifest, Weights};
use crate::runtime::{lit_f32, lit_i32, to_f32_vec, Runtime};
use crate::sparse::{make_policy, Ctx, Policy, SelectScratch};
use crate::util::rng::Rng;
use crate::util::threadpool::scoped_map_mut;
use crate::util::timer::PhaseTimer;
use anyhow::{bail, Result};
use std::path::Path;
use std::sync::Arc;
use xla::Literal;

/// View of one layer of a paged KV cache as a key source for policies.
pub struct LayerKeys<'a> {
    pub cache: &'a KvCache,
    pub layer: usize,
    pub n: usize,
}

impl KeySource for LayerKeys<'_> {
    fn dim(&self) -> usize {
        self.cache.row_dim()
    }

    fn len(&self) -> usize {
        self.n
    }

    fn key_into(&self, token: usize, out: &mut [f32]) {
        self.cache.key_row_into(self.layer, token, out)
    }

    fn try_key(&self, token: usize) -> Option<&[f32]> {
        self.cache.try_key_row(self.layer, token)
    }
}

/// Token sampling configuration.
#[derive(Clone, Debug)]
pub struct Sampling {
    pub greedy: bool,
    pub temperature: f32,
}

impl Default for Sampling {
    fn default() -> Self {
        Sampling { greedy: true, temperature: 1.0 }
    }
}

/// One in-flight sequence: prompt + generated text, its paged KV cache
/// and the per-layer retrieval policies.
pub struct Sequence {
    pub id: u64,
    pub text: Vec<u8>,
    pub kv: KvCache,
    pub policies: Vec<Box<dyn Policy>>,
    /// Tokens cached so far (== next position).
    pub pos: usize,
    pub last_logits: Vec<f32>,
    pub generated: Vec<u8>,
    pub timer: PhaseTimer,
    /// Reusable retrieval buffers shared by all of this sequence's layer
    /// policies — steady-state decode allocates nothing on the select
    /// path (buffers keep their high-water capacity across tokens).
    pub scratch: SelectScratch,
    /// Sim-engine cache of the rolling content hash over `text` (a pure
    /// function of the text; `None` until the first sim decode step).
    /// Keeps the content-seeded synthetic K/V O(1) per generated token
    /// instead of rescanning the whole history. Unused by the PJRT
    /// engine.
    pub(crate) content_seed: Option<u64>,
    rng: Rng,
}

impl Sequence {
    /// Total KV bytes held (Fig. 8).
    pub fn kv_bytes(&self) -> usize {
        self.kv.bytes()
    }

    /// Total policy index bytes (Fig. 8).
    pub fn index_bytes(&self) -> usize {
        self.policies.iter().map(|p| p.index_bytes()).sum()
    }

    /// Sample the next token from `last_logits`.
    fn sample(&mut self, s: &Sampling) -> u8 {
        if s.greedy {
            crate::linalg::argmax(&self.last_logits) as u8
        } else {
            let mut probs = self.last_logits.clone();
            for p in probs.iter_mut() {
                *p /= s.temperature.max(1e-6);
            }
            crate::linalg::softmax(&mut probs);
            let mut r = self.rng.f32();
            for (i, &p) in probs.iter().enumerate() {
                r -= p;
                if r <= 0.0 {
                    return i as u8;
                }
            }
            (probs.len() - 1) as u8
        }
    }
}

/// Resumable state of a chunked streaming prefill: the paged K/V
/// accumulated so far plus the per-layer policy indexes under
/// construction. Produced by [`EngineCore::begin_prefill`], advanced one
/// chunk at a time by [`EngineCore::prefill_chunk`] (the scheduler
/// interleaves these calls with decode steps), and converted into a
/// decode-ready [`Sequence`] by [`EngineCore::finish_prefill`]. Dropping
/// the state (e.g. on preemption) recycles every leased page.
pub struct PrefillState {
    pub(crate) id: u64,
    pub(crate) prompt: Vec<u8>,
    /// Retrieval policy name this request runs (keys the radix cache's
    /// frozen index segments).
    pub(crate) policy: String,
    pub(crate) kv: KvCache,
    pub(crate) policies: Vec<Box<dyn Policy>>,
    /// Tokens prefilled + indexed so far (== next chunk's start).
    pub(crate) done: usize,
    /// Tokens adopted from the shared-prefix radix cache (their prefill
    /// chunks were skipped entirely).
    pub(crate) prefix_reused: usize,
    /// Logits at the last prompt position (set by the final chunk).
    pub(crate) last_logits: Option<Vec<f32>>,
    pub(crate) chunks_executed: usize,
}

impl PrefillState {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn prompt(&self) -> &[u8] {
        &self.prompt
    }

    /// Tokens prefilled so far.
    pub fn done(&self) -> usize {
        self.done
    }

    /// Tokens adopted from the shared-prefix radix cache at
    /// `begin_prefill` (0 on a cold start / radix-off).
    pub fn prefix_tokens_reused(&self) -> usize {
        self.prefix_reused
    }

    pub fn total(&self) -> usize {
        self.prompt.len()
    }

    pub fn is_ready(&self) -> bool {
        self.done == self.prompt.len() && self.last_logits.is_some()
    }

    pub fn chunks_executed(&self) -> usize {
        self.chunks_executed
    }

    /// Shared back half of `finish_prefill` (PJRT and sim engines).
    pub(crate) fn into_sequence(self) -> Result<Sequence> {
        let PrefillState { id, prompt, kv, policies, done, last_logits, .. } = self;
        let Some(last_logits) = last_logits else {
            bail!("finish_prefill before the final chunk ({done}/{} tokens)", prompt.len());
        };
        Ok(Sequence {
            id,
            pos: prompt.len(),
            text: prompt,
            kv,
            policies,
            last_logits,
            generated: Vec::new(),
            timer: PhaseTimer::new(),
            scratch: SelectScratch::new(),
            content_seed: None,
            rng: Rng::new(id ^ 0x5EED),
        })
    }
}

/// Outcome of one [`EngineCore::prefill_chunk`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillProgress {
    /// More prompt remains; call `prefill_chunk` again.
    Pending,
    /// The whole prompt is prefilled; call `finish_prefill`.
    Ready,
}

/// What the continuous-batching coordinator needs from an engine: the
/// chunked-prefill state machine, batched decode, and arena accounting.
/// Implemented by the PJRT-backed [`Engine`] and by the artifact-free
/// [`sim::SimEngine`] (real policies/index/arena over synthetic K/V),
/// which lets the scheduler be tested and benchmarked — including 32k+
/// prompts beyond the compiled prefill buckets — without HLO artifacts.
pub trait EngineCore {
    /// Start a chunked prefill (validates the prompt, leases nothing yet).
    fn begin_prefill(&self, id: u64, prompt: &[u8], policy_name: &str) -> Result<PrefillState>;

    /// Process roughly `serving.prefill_chunk_tokens` further prompt
    /// tokens (0 = the whole remaining prompt; bucketed engines advance
    /// to the edge of the compute bucket the chunk already pays for):
    /// append their K/V to the paged arena and absorb them into every
    /// layer policy via [`Policy::extend`].
    fn prefill_chunk(&self, st: &mut PrefillState) -> Result<PrefillProgress>;

    /// Convert a `Ready` prefill state into a decode-ready sequence.
    fn finish_prefill(&self, st: PrefillState) -> Result<Sequence>;

    /// One decode step over a batch; returns the sampled token per
    /// sequence.
    fn decode_batch(&self, seqs: &mut [&mut Sequence], sampling: &Sampling) -> Result<Vec<u8>>;

    /// Arena bytes a sequence of `n_tokens` will lease (admission
    /// control's footprint estimate).
    fn estimate_seq_bytes(&self, n_tokens: usize) -> usize;

    /// The shared KV page arena.
    fn pool(&self) -> &Arc<PagePool>;

    /// The shared-prefix radix cache, when this engine maintains one
    /// (`kv.prefix_cache_mb > 0`). The coordinator reads its stats for
    /// the metrics scrape and sheds cold entries under arena pressure.
    fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        None
    }

    /// Longest admissible prompt in tokens.
    fn max_prompt(&self) -> usize;

    /// Total faults this engine's fault plan has fired (chaos builds
    /// only; engines without an installed plan report 0). Surfaced as
    /// `faults_injected_total` in the metrics scrape.
    fn faults_injected(&self) -> u64 {
        0
    }

    /// The engine's installed fault plan, when one exists (chaos builds
    /// only). The coordinator consults it for the *scheduler-level*
    /// sites — shard kill and heartbeat stall — which must fire outside
    /// the per-job `catch_unwind` isolation that contains engine-level
    /// faults.
    #[cfg(any(test, feature = "failpoints"))]
    fn fault_plan(&self) -> Option<&std::sync::Arc<crate::util::fault::FaultPlan>> {
        None
    }
}

/// Radix-match `st.prompt` against the shared-prefix cache and adopt the
/// hit into the freshly begun prefill state: borrow the sealed K/V pages
/// into the page table, seed each layer policy with its frozen segment
/// (or backfill its index through the normal `extend` path over the
/// adopted keys), and advance the chunked-prefill frontier past the
/// matched tokens — those chunks are skipped entirely. The match is
/// capped one token short of the prompt so the final chunk (which
/// produces the last-position logits) always runs. Shared helper of the
/// PJRT and sim engines' `begin_prefill`.
pub(crate) fn adopt_prefix_into(cache: &PrefixCache, st: &mut PrefillState) -> usize {
    if !cache.enabled() {
        return 0;
    }
    let max_pages = st.prompt.len().saturating_sub(1) / PAGE_SIZE;
    let Some(m) = cache.lookup(&st.prompt, max_pages, &st.policy) else { return 0 };
    let PrefillState { kv, policies, prompt, .. } = &mut *st;
    let Ok(tokens) = kv.adopt_prefix(&m.pages) else { return 0 };
    for (l, policy) in policies.iter_mut().enumerate() {
        let adopted = m
            .segments
            .as_ref()
            .and_then(|v| v.get(l))
            .and_then(|o| o.as_ref())
            .map_or(false, |seg| policy.adopt_segment(seg));
        if !adopted {
            // No frozen segment for this layer/policy: absorb the
            // adopted tokens through the normal incremental-build path
            // (key rows read straight from the adopted shared pages),
            // which the chunked-extend property pins as byte-exact.
            let keys = LayerKeys { cache: kv, layer: l, n: tokens };
            let ctx = Ctx { keys: &keys, text: prompt, n: tokens };
            policy.extend(&ctx, 0..tokens);
        }
    }
    st.done = tokens;
    st.prefix_reused = tokens;
    tokens
}

/// Seal-back half of the radix lifecycle, shared by both engines'
/// `finish_prefill`: seal the prompt's full pages into shared pages,
/// export each layer policy's frozen segment, and insert the prefix into
/// the radix cache (existing nodes win; LRU eviction keeps the cache
/// within `kv.prefix_cache_mb`).
pub(crate) fn seal_prefix_back(cache: &PrefixCache, st: &mut PrefillState) {
    if !cache.enabled() {
        return;
    }
    let sealable = (st.prompt.len() / PAGE_SIZE) * PAGE_SIZE;
    if sealable == 0 {
        return;
    }
    let pages = st.kv.seal_prefix(sealable);
    let segments: Vec<Option<crate::sparse::PolicySegment>> =
        st.policies.iter().map(|p| p.export_segment(sealable)).collect();
    cache.insert(&st.prompt[..sealable], pages, &st.policy, segments);
}

/// Run `f` once per layer policy with that layer's key view — the shared
/// build/extend loop of the prefill, synthetic-sequence, and sim paths.
pub(crate) fn for_each_policy_ctx(
    kv: &KvCache,
    text: &[u8],
    n: usize,
    policies: &mut [Box<dyn Policy>],
    mut f: impl FnMut(&mut dyn Policy, &Ctx),
) {
    for (l, p) in policies.iter_mut().enumerate() {
        let keys = LayerKeys { cache: kv, layer: l, n };
        let ctx = Ctx { keys: &keys, text, n };
        f(p.as_mut(), &ctx);
    }
}

/// The engine: runtime + weights + device-cached weight literals + the
/// shared KV page arena every sequence leases from.
pub struct Engine {
    pub rt: Runtime,
    pub weights: Weights,
    pub cfg: Config,
    /// Literals per weight tensor, in canonical (manifest) order.
    wlits: Vec<Literal>,
    /// Shared KV page arena (capacity from `serving.kv_pool_mb`).
    pool: Arc<PagePool>,
    /// Shared-prefix radix cache (capacity from `kv.prefix_cache_mb`;
    /// disabled at 0).
    prefix: Arc<PrefixCache>,
}

impl Engine {
    pub fn load(cfg: Config) -> Result<Engine> {
        let manifest = Manifest::load(Path::new(&cfg.artifacts_dir))?;
        let weights = Weights::load(&manifest)?;
        let rt = Runtime::new(manifest)?;
        let mut wlits = Vec::new();
        for (_name, data, shape) in weights.flat_order() {
            wlits.push(lit_f32(data, shape)?);
        }
        let pool = PagePool::with_capacity(cfg.serving.kv_pool_mb.saturating_mul(1024 * 1024));
        let prefix = PrefixCache::new(cfg.kv.prefix_cache_mb);
        Ok(Engine { rt, weights, cfg, wlits, pool, prefix })
    }

    /// The shared KV page arena (admission control reads its accounting).
    pub fn pool(&self) -> &Arc<PagePool> {
        &self.pool
    }

    /// Estimated arena bytes a sequence of `n_tokens` will lease — the
    /// coordinator's admission-control footprint for a request, in the
    /// arena's real element size (`kv.precision`): narrow precisions
    /// admit proportionally more resident sequences at a fixed pool.
    pub fn estimate_seq_bytes(&self, n_tokens: usize) -> usize {
        let dims = self.dims();
        KvCache::estimate_bytes_at(
            dims.layers,
            dims.heads,
            dims.head_dim,
            n_tokens,
            self.cfg.kv.precision,
        )
    }

    /// Resolve retrieval parallelism for a decode batch of `batch`
    /// sequences (config `serving.retrieval_threads`; 0 = auto).
    fn retrieval_threads(&self, batch: usize) -> usize {
        let configured = self.cfg.serving.retrieval_threads;
        let t = if configured == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            configured
        };
        t.clamp(1, batch.max(1))
    }

    pub fn dims(&self) -> &crate::model::ModelDims {
        &self.rt.manifest.dims
    }

    fn wlit(&self, name: &str) -> &Literal {
        let pos = self
            .weights
            .tensors
            .tensors
            .iter()
            .position(|t| t.name == name)
            .unwrap_or_else(|| panic!("weight {name}"));
        &self.wlits[pos]
    }

    fn layer_lit(&self, l: usize, t: &str) -> &Literal {
        // canonical order: 8 tensors per layer, then ln_f, emb
        let pos = crate::model::LAYER_TENSORS
            .iter()
            .position(|&x| x == t)
            .unwrap_or_else(|| panic!("unknown layer tensor {t}"));
        &self.wlits[l * 8 + pos]
    }

    /// Per-layer policy roster: the first `full_attn_layers` keep full
    /// attention (paper Appendix A), the rest run `policy_name`.
    fn make_policies(&self, policy_name: &str) -> Result<Vec<Box<dyn Policy>>> {
        let dims = self.dims();
        (0..dims.layers)
            .map(|l| {
                let name = if l < self.cfg.lychee.full_attn_layers {
                    "full"
                } else {
                    policy_name
                };
                make_policy(name, &self.cfg.lychee, l, dims.layers)
                    .ok_or_else(|| crate::sparse::unknown_policy_error(name))
            })
            .collect()
    }

    /// Prefill a whole prompt; returns a ready-to-decode sequence
    /// (Algorithm 1, phase 1). Drive-to-completion wrapper over the
    /// chunked state machine — the eval harness and examples use this;
    /// the serving scheduler drives [`EngineCore::prefill_chunk`] itself
    /// so decode steps interleave with the chunks.
    pub fn prefill(&self, id: u64, prompt: &[u8], policy_name: &str) -> Result<Sequence> {
        let mut st = EngineCore::begin_prefill(self, id, prompt, policy_name)?;
        while EngineCore::prefill_chunk(self, &mut st)? == PrefillProgress::Pending {}
        EngineCore::finish_prefill(self, st)
    }

    /// Build a sequence with synthetic KV content of `n_tokens` (for the
    /// long-context latency benches where transformer prefill at 64k on
    /// CPU is impractical — TPOT depends on shapes, not values).
    pub fn synth_sequence(
        &self,
        id: u64,
        n_tokens: usize,
        policy_name: &str,
        seed: u64,
    ) -> Result<Sequence> {
        let dims = self.dims().clone();
        let mut rng = Rng::new(seed);
        let mut kv = KvCache::with_pool_precision(
            dims.layers,
            dims.heads,
            dims.head_dim,
            Arc::clone(&self.pool),
            self.cfg.kv.precision,
        );
        let row = dims.d_model;
        let text: Vec<u8> = (0..n_tokens)
            .map(|_| b"lorem ipsum, dolor sit. amet\n"[rng.range(0, 29)])
            .collect();
        for _ in 0..n_tokens {
            let k_rows: Vec<Vec<f32>> = (0..dims.layers).map(|_| rng.normal_vec(row)).collect();
            let v_rows: Vec<Vec<f32>> = (0..dims.layers).map(|_| rng.normal_vec(row)).collect();
            let kr: Vec<&[f32]> = k_rows.iter().map(|r| r.as_slice()).collect();
            let vr: Vec<&[f32]> = v_rows.iter().map(|r| r.as_slice()).collect();
            kv.append_token(&kr, &vr)?;
        }
        let mut policies = self.make_policies(policy_name)?;
        for_each_policy_ctx(&kv, &text, n_tokens, &mut policies, |p, ctx| p.build(ctx));
        Ok(Sequence {
            id,
            text,
            kv,
            policies,
            pos: n_tokens,
            last_logits: vec![0.0; dims.vocab],
            generated: Vec::new(),
            timer: PhaseTimer::new(),
            scratch: SelectScratch::new(),
            content_seed: None,
            rng: Rng::new(seed ^ 0xABCD),
        })
    }

    /// One decode step for a batch of sequences (any size up to the
    /// largest compiled batch bucket). Returns the sampled token per
    /// sequence.
    pub fn decode_batch(&self, seqs: &mut [&mut Sequence], sampling: &Sampling) -> Result<Vec<u8>> {
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        let dims = self.dims().clone();
        let b_real = seqs.len();
        let b = self.rt.batch_bucket(b_real)?;
        let (h, dh, d) = (dims.heads, dims.head_dim, dims.d_model);

        // sample this step's input token per sequence
        let mut step_tokens = Vec::with_capacity(b_real);
        for s in seqs.iter_mut() {
            let t = s.sample(sampling);
            s.text.push(t);
            s.generated.push(t);
            step_tokens.push(t);
        }

        // ---- embed -----------------------------------------------------
        let mut tok_ids = vec![0i32; b];
        let mut positions = vec![0i32; b];
        for (i, s) in seqs.iter().enumerate() {
            tok_ids[i] = step_tokens[i] as i32;
            positions[i] = s.pos as i32;
        }
        let t_embed = std::time::Instant::now();
        let tok_lit = lit_i32(&tok_ids, &[b])?;
        let x_lit = self
            .rt
            .exec(&format!("embed_b{b}"), &[self.wlit("emb"), &tok_lit])?
            .remove(0);
        let mut x = to_f32_vec(&x_lit)?;
        let d_embed = t_embed.elapsed() / b_real as u32;
        for s in seqs.iter_mut() {
            s.timer.add("embed", d_embed);
        }

        let pos_lit = lit_i32(&positions, &[b])?;
        let retr_threads = self.retrieval_threads(b_real);

        for l in 0..dims.layers {
            // ---- qkv ----------------------------------------------------
            let t0 = std::time::Instant::now();
            let x_in = lit_f32(&x, &[b, d])?;
            let qkv = self.rt.exec(
                &format!("qkv_b{b}"),
                &[
                    &x_in,
                    self.layer_lit(l, "ln1"),
                    self.layer_lit(l, "wq"),
                    self.layer_lit(l, "wk"),
                    self.layer_lit(l, "wv"),
                    &pos_lit,
                ],
            )?;
            let q_all = to_f32_vec(&qkv[0])?; // [b,H,Dh]
            let k_all = to_f32_vec(&qkv[1])?;
            let v_all = to_f32_vec(&qkv[2])?;
            let d_qkv = t0.elapsed() / b_real as u32;

            // append new k/v rows to each sequence's cache (layer l)
            for (i, s) in seqs.iter_mut().enumerate() {
                s.timer.add("qkv", d_qkv);
                let kr = &k_all[i * d..(i + 1) * d];
                let vr = &v_all[i * d..(i + 1) * d];
                s.kv.append_row(l, kr, vr);
            }

            // ---- retrieval (the L3 contribution) ------------------------
            // Policy select is per-sequence independent and read-only
            // over the shared arena (each sequence owns its pages), so
            // the batch shards onto scoped threads; the device step
            // below stays serial. Scoped spawns cost ~10µs each and run
            // only when retr_threads > 1 (batch 1 stays a plain loop);
            // per-sequence select at long context is 100µs–ms, so the
            // spawn overhead amortizes — a persistent lending worker
            // pool would shave the remainder if profiles ever show it.
            let selections: Vec<Vec<usize>> = scoped_map_mut(seqs, retr_threads, |i, s| {
                let t1 = std::time::Instant::now();
                let q = &q_all[i * d..(i + 1) * d];
                let s: &mut Sequence = &mut **s;
                let Sequence { kv, policies, text, pos, scratch, .. } = &mut *s;
                let keys = LayerKeys { cache: kv, layer: l, n: *pos + 1 };
                let ctx = Ctx { keys: &keys, text, n: *pos };
                // allocation-free select into the sequence's scratch; the
                // output buffer is taken here and handed back (recycled)
                // after the batched gather below, so steady-state decode
                // performs zero allocations on the retrieval path
                policies[l].select_into(&ctx, q, *pos, scratch);
                scratch.out.push(*pos); // self-attention to the current token
                let sel = std::mem::take(&mut scratch.out);
                s.timer.add("retrieval", t1.elapsed());
                sel
            });

            // ---- gather + attention -------------------------------------
            let max_active = selections.iter().map(|s| s.len()).max().unwrap_or(0);
            let m = self.rt.attn_bucket(b, max_active)?;
            let t2 = std::time::Instant::now();
            let row = d;
            let mut k_batch = vec![0.0f32; b * m * row];
            let mut v_batch = vec![0.0f32; b * m * row];
            let mut mask_batch = vec![0.0f32; b * m];
            {
                // each sequence gathers straight into its disjoint slice
                // of the batch buffers, in parallel with the others
                let caches: Vec<&KvCache> = seqs.iter().map(|s| &s.kv).collect();
                crate::kvcache::gather_batch_into(
                    &caches,
                    l,
                    &selections,
                    m,
                    &mut k_batch,
                    &mut v_batch,
                    &mut mask_batch,
                    retr_threads,
                );
            }
            let q_lit = lit_f32(&q_all, &[b, h, dh])?;
            let k_lit = lit_f32(&k_batch, &[b, m, h, dh])?;
            let v_lit = lit_f32(&v_batch, &[b, m, h, dh])?;
            let mask_lit = lit_f32(&mask_batch, &[b, m])?;
            let d_gather = t2.elapsed() / b_real as u32;

            // hand each selection buffer back to its sequence's scratch so
            // the next layer/token reuses the allocation
            for (s, mut sel) in seqs.iter_mut().zip(selections) {
                sel.clear();
                s.scratch.out = sel;
            }

            let t3 = std::time::Instant::now();
            let attn = self
                .rt
                .exec(&format!("attn_b{b}_m{m}"), &[&q_lit, &k_lit, &v_lit, &mask_lit])?
                .remove(0);
            let d_attn = t3.elapsed() / b_real as u32;

            // ---- ffn ----------------------------------------------------
            let t4 = std::time::Instant::now();
            let x_resid = lit_f32(&x, &[b, d])?;
            let x_out = self.rt.exec(
                &format!("proj_ffn_b{b}"),
                &[
                    &attn,
                    &x_resid,
                    self.layer_lit(l, "wo"),
                    self.layer_lit(l, "ln2"),
                    self.layer_lit(l, "w1"),
                    self.layer_lit(l, "w2"),
                ],
            )?;
            x = to_f32_vec(&x_out[0])?;
            let d_ffn = t4.elapsed() / b_real as u32;
            for s in seqs.iter_mut() {
                s.timer.add("gather", d_gather);
                s.timer.add("attention", d_attn);
                s.timer.add("ffn", d_ffn);
            }
        }

        // ---- lm head ----------------------------------------------------
        let t5 = std::time::Instant::now();
        let x_lit = lit_f32(&x, &[b, d])?;
        let logits = self
            .rt
            .exec(&format!("lm_head_b{b}"), &[&x_lit, self.wlit("ln_f"), self.wlit("emb")])?
            .remove(0);
        let logits_all = to_f32_vec(&logits)?;
        let d_head = t5.elapsed() / b_real as u32;

        // ---- commit + lazy index update (parallel across sequences) ------
        let vocab = dims.vocab;
        scoped_map_mut(seqs, retr_threads, |i, s| {
            let s: &mut Sequence = &mut **s;
            s.timer.add("lm_head", d_head);
            s.kv.commit_token();
            let t6 = std::time::Instant::now();
            let Sequence { kv, policies, text, pos, .. } = &mut *s;
            for (l, policy) in policies.iter_mut().enumerate() {
                let keys = LayerKeys { cache: kv, layer: l, n: *pos + 1 };
                let ctx = Ctx { keys: &keys, text, n: *pos + 1 };
                policy.on_token(&ctx, *pos);
            }
            s.timer.add("update", t6.elapsed());
            s.pos += 1;
            s.last_logits = logits_all[i * vocab..(i + 1) * vocab].to_vec();
        });
        Ok(step_tokens)
    }

    /// Convenience: single-sequence decode step.
    pub fn decode_step(&self, seq: &mut Sequence, sampling: &Sampling) -> Result<u8> {
        let mut refs = [seq];
        Ok(self.decode_batch(&mut refs, sampling)?[0])
    }

    /// Generate `n` tokens greedily; returns the generated bytes.
    pub fn generate(&self, seq: &mut Sequence, n: usize) -> Result<Vec<u8>> {
        let sampling = Sampling::default();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.decode_step(seq, &sampling)?);
        }
        Ok(out)
    }
}

impl EngineCore for Engine {
    fn begin_prefill(&self, id: u64, prompt: &[u8], policy_name: &str) -> Result<PrefillState> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        // fail before any pages are leased if no bucket covers the prompt
        self.rt.prefill_bucket(prompt.len())?;
        let dims = self.dims();
        let kv = KvCache::with_pool_precision(
            dims.layers,
            dims.heads,
            dims.head_dim,
            Arc::clone(&self.pool),
            self.cfg.kv.precision,
        );
        let policies = self.make_policies(policy_name)?;
        let mut st = PrefillState {
            id,
            prompt: prompt.to_vec(),
            policy: policy_name.to_string(),
            kv,
            policies,
            done: 0,
            prefix_reused: 0,
            last_logits: None,
            chunks_executed: 0,
        };
        adopt_prefix_into(&self.prefix, &mut st);
        Ok(st)
    }

    /// One streaming-prefill chunk. The compiled prefill programs are
    /// self-contained (weights + token ids + valid length — no past-KV
    /// input), so each chunk runs the *prefix* `[0, end)` through the
    /// smallest bucket covering `end` and harvests only the new K/V rows
    /// `[done, end)`: causal attention with exact padding masks makes a
    /// prefix row independent of bucket size, and the final chunk runs
    /// the very same program invocation as a monolithic prefill, so its
    /// logits are bit-identical to the unchunked path.
    fn prefill_chunk(&self, st: &mut PrefillState) -> Result<PrefillProgress> {
        let total = st.prompt.len();
        if st.done >= total {
            return Ok(PrefillProgress::Ready);
        }
        let chunk = self.cfg.serving.prefill_chunk_tokens;
        let target = if chunk == 0 { total } else { (st.done + chunk).min(total) };
        // Fill the bucket we are already paying for: the chunk's program
        // recomputes the whole prefix at `bucket(target)` regardless of
        // how few new tokens it covers, so advancing to the bucket edge
        // costs the same per-tick stall while minimizing total recompute
        // (with the seed's coarse {128, 2048} buckets, a smaller step
        // would multiply prefill FLOPs for zero latency benefit).
        let s_bucket = self.rt.prefill_bucket(target)?;
        let end = s_bucket.min(total);
        let mut tokens = vec![0i32; s_bucket];
        for (i, &b) in st.prompt[..end].iter().enumerate() {
            tokens[i] = b as i32;
        }
        let tok_lit = lit_i32(&tokens, &[s_bucket])?;
        let len_lit = Literal::scalar(end as i32);
        let mut args: Vec<&Literal> = self.wlits.iter().collect();
        args.push(&tok_lit);
        args.push(&len_lit);
        let outs = self.rt.exec(&format!("prefill_s{s_bucket}"), &args)?;
        let k_flat = to_f32_vec(&outs[0])?;
        let v_flat = to_f32_vec(&outs[1])?;
        st.kv.load_prefill_range(&k_flat, &v_flat, s_bucket, st.done, end)?;
        let from = st.done;
        for_each_policy_ctx(&st.kv, &st.prompt, end, &mut st.policies, |p, ctx| {
            p.extend(ctx, from..end)
        });
        st.done = end;
        st.chunks_executed += 1;
        if end == total {
            st.last_logits = Some(to_f32_vec(&outs[3])?);
            Ok(PrefillProgress::Ready)
        } else {
            Ok(PrefillProgress::Pending)
        }
    }

    fn finish_prefill(&self, mut st: PrefillState) -> Result<Sequence> {
        seal_prefix_back(&self.prefix, &mut st);
        st.into_sequence()
    }

    fn decode_batch(&self, seqs: &mut [&mut Sequence], sampling: &Sampling) -> Result<Vec<u8>> {
        Engine::decode_batch(self, seqs, sampling)
    }

    fn estimate_seq_bytes(&self, n_tokens: usize) -> usize {
        Engine::estimate_seq_bytes(self, n_tokens)
    }

    fn pool(&self) -> &Arc<PagePool> {
        Engine::pool(self)
    }

    fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        Some(&self.prefix)
    }

    fn max_prompt(&self) -> usize {
        self.rt.max_prompt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let mut cfg = Config::new();
        cfg.artifacts_dir = dir.to_str().unwrap().to_string();
        Some(Engine::load(cfg).unwrap())
    }

    #[test]
    fn prefill_produces_kv_and_logits() {
        let Some(eng) = engine() else { return };
        let seq = eng.prefill(1, b"Hello, lychee cluster!", "full").unwrap();
        assert_eq!(seq.pos, 22);
        assert_eq!(seq.kv.len(), 22);
        assert_eq!(seq.last_logits.len(), 256);
        assert!(seq.last_logits.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn decode_steps_are_deterministic() {
        let Some(eng) = engine() else { return };
        let mut a = eng.prefill(1, b"The quick brown fox.", "full").unwrap();
        let mut b = eng.prefill(2, b"The quick brown fox.", "full").unwrap();
        let ta = eng.generate(&mut a, 8).unwrap();
        let tb = eng.generate(&mut b, 8).unwrap();
        assert_eq!(ta, tb);
        assert_eq!(a.pos, 28);
        assert_eq!(a.kv.len(), 28);
    }

    #[test]
    fn lychee_policy_decodes_and_stays_consistent() {
        let Some(eng) = engine() else { return };
        let prompt: Vec<u8> =
            b"fn main() { println!(\"hi\"); } // some code, and prose. More text here!".to_vec();
        let mut seq = eng.prefill(3, &prompt, "lychee").unwrap();
        let toks = eng.generate(&mut seq, 6).unwrap();
        assert_eq!(toks.len(), 6);
        assert_eq!(seq.pos, prompt.len() + 6);
        // budget >> context: lychee degenerates to full attention, so the
        // generated tokens must match the full policy exactly
        let mut full = eng.prefill(4, &prompt, "full").unwrap();
        let toks_full = eng.generate(&mut full, 6).unwrap();
        assert_eq!(toks, toks_full, "degenerate lychee != full");
    }

    #[test]
    fn batched_decode_matches_single() {
        let Some(eng) = engine() else { return };
        let s = Sampling::default();
        let mut a1 = eng.prefill(1, b"alpha beta gamma", "full").unwrap();
        let mut a2 = eng.prefill(2, b"one two three four", "full").unwrap();
        let t1 = eng.decode_step(&mut a1, &s).unwrap();
        let t2 = eng.decode_step(&mut a2, &s).unwrap();
        let mut b1 = eng.prefill(1, b"alpha beta gamma", "full").unwrap();
        let mut b2 = eng.prefill(2, b"one two three four", "full").unwrap();
        let mut batch = [&mut b1, &mut b2];
        let toks = eng.decode_batch(&mut batch, &s).unwrap();
        assert_eq!(toks, vec![t1, t2]);
        for (x, y) in a1.last_logits.iter().zip(&b1.last_logits) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn synth_sequence_long_context_decode() {
        let Some(eng) = engine() else { return };
        let mut seq = eng.synth_sequence(9, 3000, "lychee", 7).unwrap();
        let s = Sampling::default();
        let _t = eng.decode_step(&mut seq, &s).unwrap();
        assert_eq!(seq.pos, 3001);
        // retrieval must have produced a bounded active set (budget 1024)
        let counts = eng.rt.exec_counts.borrow();
        assert!(
            counts.keys().any(|k| k.starts_with("attn_b1_m1024") || k.starts_with("attn_b1_m2048")),
            "expected small attn bucket, got {:?}",
            counts.keys().collect::<Vec<_>>()
        );
        assert!(seq.index_bytes() > 0);
        assert!(seq.kv_bytes() > 3000 * 128 * 4 * 2);
    }

    #[test]
    fn chunked_prefill_matches_monolithic_decode() {
        // The engine-level half of the streaming-prefill property: a
        // prompt prefilled in small chunks must decode to the same
        // tokens (and near-identical logits) as the monolithic path,
        // for both a stateless and an index-building policy.
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        // > 128 tokens so the seed's {128, 2048} prefill buckets split
        // the prompt into two genuine chunks (chunk advances to the
        // bucket edge it is paying for)
        let prompt: Vec<u8> = crate::workloads::trace::prompt_text(300, 17);
        for policy in ["full", "lychee", "quest"] {
            let mut mono_cfg = Config::new();
            mono_cfg.artifacts_dir = dir.to_str().unwrap().to_string();
            mono_cfg.serving.prefill_chunk_tokens = 0; // monolithic
            let mono_eng = Engine::load(mono_cfg).unwrap();
            let mut mono = mono_eng.prefill(1, &prompt, policy).unwrap();
            let mono_prefill_logits = mono.last_logits.clone();
            let mono_toks = mono_eng.generate(&mut mono, 6).unwrap();

            let mut chunk_cfg = Config::new();
            chunk_cfg.artifacts_dir = dir.to_str().unwrap().to_string();
            chunk_cfg.serving.prefill_chunk_tokens = 64;
            let chunk_eng = Engine::load(chunk_cfg).unwrap();
            let mut st = chunk_eng.begin_prefill(1, &prompt, policy).unwrap();
            // chunk 1: target 64 -> bucket 128 -> done = 128
            assert_eq!(chunk_eng.prefill_chunk(&mut st).unwrap(), PrefillProgress::Pending);
            assert_eq!(st.done(), 128);
            // chunk 2: target 192 -> bucket 2048 -> done = 300 (total)
            assert_eq!(chunk_eng.prefill_chunk(&mut st).unwrap(), PrefillProgress::Ready);
            assert_eq!(st.done(), 300);
            assert_eq!(st.chunks_executed(), 2);
            let mut seq = chunk_eng.finish_prefill(st).unwrap();
            assert_eq!(seq.pos, prompt.len());
            assert_eq!(seq.kv.len(), prompt.len());
            // final-chunk logits come from the same program invocation as
            // the monolithic prefill: bit-identical
            assert_eq!(seq.last_logits, mono_prefill_logits, "policy {policy}");
            let chunk_toks = chunk_eng.generate(&mut seq, 6).unwrap();
            assert_eq!(chunk_toks, mono_toks, "policy {policy}: chunked decode diverged");
        }
    }

    #[test]
    fn finish_prefill_rejects_incomplete_state() {
        let Some(eng) = engine() else { return };
        let mut cfg2 = eng.cfg.clone();
        cfg2.serving.prefill_chunk_tokens = 64;
        let eng2 = Engine::load(cfg2).unwrap();
        // 200 tokens: the first 64-token chunk advances to the 128 bucket
        // edge, leaving the prefill mid-flight
        let prompt = crate::workloads::trace::prompt_text(200, 3);
        let mut st = eng2.begin_prefill(1, &prompt, "full").unwrap();
        assert_eq!(eng2.prefill_chunk(&mut st).unwrap(), PrefillProgress::Pending);
        assert!(!st.is_ready());
        assert!(eng2.finish_prefill(st).is_err());
        // empty prompts are rejected before any pages lease
        assert!(eng2.begin_prefill(2, b"", "full").is_err());
    }

    #[test]
    fn phase_timer_populated() {
        let Some(eng) = engine() else { return };
        let mut seq = eng.prefill(5, b"timing test prompt.", "lychee").unwrap();
        eng.generate(&mut seq, 3).unwrap();
        for phase in ["embed", "qkv", "retrieval", "gather", "attention", "ffn", "update"] {
            assert!(seq.timer.count(phase) > 0, "missing phase {phase}");
        }
    }
}
