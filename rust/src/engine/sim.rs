//! Artifact-free scheduler engine: real retrieval policies, hierarchical
//! indexes, and the shared paged arena — but synthetic K/V rows and
//! logits instead of PJRT programs. Implements [`EngineCore`] so the
//! continuous-batching coordinator, its starvation/preemption tests, and
//! the `serving_json` bench all run without compiled HLO artifacts, and
//! with prompts (32k+) far beyond the compiled prefill buckets.
//!
//! What is real here: chunked-prefill scheduling, `Policy::extend`
//! incremental index builds, per-step `select_into` + arena gathers,
//! lazy `on_token` updates, page leasing/recycling, and admission
//! accounting. What is synthetic: K/V row values (seeded per
//! token/layer), logits (zeros — greedy decode deterministically emits
//! token 0), and an optional spin-wait emulating HLO compute so latency
//! experiments have a realistic long pole.

use super::{
    adopt_prefix_into, for_each_policy_ctx, seal_prefix_back, EngineCore, LayerKeys,
    PrefillProgress, PrefillState, Sampling, Sequence,
};
use crate::config::Config;
use crate::kvcache::{KvCache, PagePool, PrefixCache};
use crate::sparse::{make_policy, Ctx, Policy};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::sync::Arc;

/// FNV-1a over a byte prefix — the *content seed* for synthetic K/V.
fn fnv(text: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in text {
        h = fnv_step(h, b);
    }
    h
}

#[inline]
fn fnv_step(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3)
}

/// Shape + synthetic-compute parameters of a [`SimEngine`].
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    /// Longest admissible prompt (a real engine is bounded by its largest
    /// compiled prefill bucket; the sim has no such ceiling).
    pub max_prompt: usize,
    /// Spin-wait per prefilled token, emulating the HLO prefill cost —
    /// this is what makes a monolithic long prefill a measurable stall.
    pub prefill_us_per_token: u64,
    /// Spin-wait per decode step, emulating the HLO decode cost.
    pub decode_us_per_step: u64,
    /// Deterministic fault plan for chaos tests (seed + per-site rates);
    /// `None` = no injection. Chaos builds only — construct with
    /// struct-update syntax (`..SimConfig::default()`) so plain builds
    /// never name the field.
    #[cfg(any(test, feature = "failpoints"))]
    pub faults: Option<crate::util::fault::FaultSpec>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            layers: 2,
            heads: 2,
            head_dim: 8,
            vocab: 64,
            max_prompt: 256 * 1024,
            prefill_us_per_token: 0,
            decode_us_per_step: 0,
            #[cfg(any(test, feature = "failpoints"))]
            faults: None,
        }
    }
}

/// The simulated engine. Shares [`PrefillState`]/[`Sequence`] with the
/// PJRT engine, so the coordinator code under test is byte-for-byte the
/// production scheduler.
pub struct SimEngine {
    cfg: Config,
    sim: SimConfig,
    pool: Arc<PagePool>,
    prefix: Arc<PrefixCache>,
    /// Built from `SimConfig::faults` and also installed on the pool
    /// (page-lease refusals), so one seed drives every injection site.
    #[cfg(any(test, feature = "failpoints"))]
    fault: Option<Arc<crate::util::fault::FaultPlan>>,
}

impl SimEngine {
    pub fn new(cfg: Config, sim: SimConfig) -> SimEngine {
        let pool = PagePool::with_capacity(cfg.serving.kv_pool_mb.saturating_mul(1024 * 1024));
        let prefix = PrefixCache::new(cfg.kv.prefix_cache_mb);
        #[cfg(any(test, feature = "failpoints"))]
        let fault = sim.faults.clone().map(|spec| {
            let plan = Arc::new(crate::util::fault::FaultPlan::new(spec));
            pool.set_fault_plan(Arc::clone(&plan));
            plan
        });
        SimEngine {
            cfg,
            sim,
            pool,
            prefix,
            #[cfg(any(test, feature = "failpoints"))]
            fault,
        }
    }

    fn row_dim(&self) -> usize {
        self.sim.heads * self.sim.head_dim
    }

    /// Deterministic synthetic row, seeded by the **content hash** of
    /// the text prefix up to and including the row's token (plus layer
    /// and K/V/query kind). Like a real model's K/V, the row is a pure
    /// function of the prefix *content* — never of the sequence id — so
    /// two sequences sharing a prompt prefix have byte-identical rows
    /// for it. This is the property the shared-prefix radix cache
    /// adopts pages under, and what makes radix-hit prefill byte-exact
    /// vs a cold one.
    fn synth_row(&self, content_seed: u64, layer: usize, kind: u64) -> Vec<f32> {
        let seed = content_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((layer as u64).wrapping_mul(0x94D0_49BB_1331_11EB))
            ^ kind;
        Rng::new(seed).normal_vec(self.row_dim())
    }

    fn make_policies(&self, policy_name: &str) -> Result<Vec<Box<dyn Policy>>> {
        (0..self.sim.layers)
            .map(|l| {
                let name = if l < self.cfg.lychee.full_attn_layers {
                    "full"
                } else {
                    policy_name
                };
                make_policy(name, &self.cfg.lychee, l, self.sim.layers)
                    .ok_or_else(|| crate::sparse::unknown_policy_error(name))
            })
            .collect()
    }

    /// Spin-wait emulating device compute (sleep granularity is too
    /// coarse for chunk-scale costs).
    fn busy(&self, us: u64) {
        if us == 0 {
            return;
        }
        let t = std::time::Instant::now();
        let dur = std::time::Duration::from_micros(us);
        while t.elapsed() < dur {
            std::hint::spin_loop();
        }
    }
}

impl EngineCore for SimEngine {
    fn begin_prefill(&self, id: u64, prompt: &[u8], policy_name: &str) -> Result<PrefillState> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() > self.sim.max_prompt {
            bail!("prompt of {} tokens exceeds largest prefill bucket", prompt.len());
        }
        let kv = KvCache::with_pool_precision(
            self.sim.layers,
            self.sim.heads,
            self.sim.head_dim,
            Arc::clone(&self.pool),
            self.cfg.kv.precision,
        );
        let policies = self.make_policies(policy_name)?;
        let mut st = PrefillState {
            id,
            prompt: prompt.to_vec(),
            policy: policy_name.to_string(),
            kv,
            policies,
            done: 0,
            prefix_reused: 0,
            last_logits: None,
            chunks_executed: 0,
        };
        adopt_prefix_into(&self.prefix, &mut st);
        Ok(st)
    }

    fn prefill_chunk(&self, st: &mut PrefillState) -> Result<PrefillProgress> {
        let total = st.prompt.len();
        if st.done >= total {
            return Ok(PrefillProgress::Ready);
        }
        let chunk = self.cfg.serving.prefill_chunk_tokens;
        let end = if chunk == 0 { total } else { (st.done + chunk).min(total) };
        // Fault site (chaos builds): a stalled chunk spins before any
        // work, keyed by the sequence's own chunk counter so the
        // schedule is interleaving-independent.
        #[cfg(any(test, feature = "failpoints"))]
        if let Some(us) =
            self.fault.as_ref().and_then(|p| p.prefill_stall_us(st.id, st.chunks_executed as u64))
        {
            self.busy(us);
        }
        let mut h = fnv(&st.prompt[..st.done]);
        for t in st.done..end {
            h = fnv_step(h, st.prompt[t]);
            let k_rows: Vec<Vec<f32>> =
                (0..self.sim.layers).map(|l| self.synth_row(h, l, 0xA0)).collect();
            let v_rows: Vec<Vec<f32>> =
                (0..self.sim.layers).map(|l| self.synth_row(h, l, 0xB0)).collect();
            let kr: Vec<&[f32]> = k_rows.iter().map(|r| r.as_slice()).collect();
            let vr: Vec<&[f32]> = v_rows.iter().map(|r| r.as_slice()).collect();
            st.kv.append_token(&kr, &vr)?;
        }
        let from = st.done;
        for_each_policy_ctx(&st.kv, &st.prompt, end, &mut st.policies, |p, ctx| {
            p.extend(ctx, from..end)
        });
        self.busy(self.sim.prefill_us_per_token.saturating_mul((end - from) as u64));
        st.done = end;
        st.chunks_executed += 1;
        if end == total {
            st.last_logits = Some(vec![0.0; self.sim.vocab]);
            Ok(PrefillProgress::Ready)
        } else {
            Ok(PrefillProgress::Pending)
        }
    }

    fn finish_prefill(&self, mut st: PrefillState) -> Result<Sequence> {
        seal_prefix_back(&self.prefix, &mut st);
        st.into_sequence()
    }

    /// One decode step: per sequence, append a synthetic K/V row per
    /// layer, run the real per-layer retrieval (`select_into` + arena
    /// gather) and the real lazy index update — the same call sequence
    /// as [`super::Engine::decode_batch`], minus the PJRT stages.
    fn decode_batch(&self, seqs: &mut [&mut Sequence], sampling: &Sampling) -> Result<Vec<u8>> {
        let layers = self.sim.layers;
        let mut toks = Vec::with_capacity(seqs.len());
        let (mut kbuf, mut vbuf, mut mbuf) = (Vec::new(), Vec::new(), Vec::new());
        for s in seqs.iter_mut() {
            let s: &mut Sequence = &mut **s;
            // Fault sites (chaos builds): a panicking step fires BEFORE
            // this sequence mutates anything, so earlier batch members
            // are fully stepped and later ones untouched; a stalled
            // step spins first.
            #[cfg(any(test, feature = "failpoints"))]
            if let Some(plan) = self.fault.as_ref() {
                if plan.panic_at_step(s.id, s.pos as u64) {
                    panic!("injected fault: engine panic at seq {} pos {}", s.id, s.pos);
                }
                if let Some(us) = plan.decode_stall_us(s.id, s.pos as u64) {
                    self.busy(us);
                }
            }
            let t = s.sample(sampling);
            s.text.push(t);
            s.generated.push(t);
            toks.push(t);
            // content seed over text[0..=pos] (the just-pushed token's
            // prefix): rows depend only on content, never the seq id.
            // The rolling hash is cached on the sequence — the first
            // step pays one O(text) scan, every later step is O(1).
            let h = match s.content_seed {
                Some(prev) => fnv_step(prev, t),
                None => fnv(&s.text[..s.pos + 1]),
            };
            s.content_seed = Some(h);
            for l in 0..layers {
                let kr = self.synth_row(h, l, 0xA0);
                let vr = self.synth_row(h, l, 0xB0);
                s.kv.append_row(l, &kr, &vr);
            }
            let queries: Vec<Vec<f32>> = (0..layers).map(|l| self.synth_row(h, l, 0xC0)).collect();
            let Sequence { kv, policies, text, pos, scratch, .. } = &mut *s;
            for (l, q) in queries.iter().enumerate() {
                let keys = LayerKeys { cache: kv, layer: l, n: *pos + 1 };
                let ctx = Ctx { keys: &keys, text, n: *pos };
                policies[l].select_into(&ctx, q, *pos, scratch);
                scratch.out.push(*pos);
                let bucket = scratch.out.len().next_power_of_two();
                kv.gather(l, &scratch.out, bucket, &mut kbuf, &mut vbuf, &mut mbuf);
                scratch.out.clear();
            }
            kv.commit_token();
            for l in 0..layers {
                let keys = LayerKeys { cache: kv, layer: l, n: *pos + 1 };
                let ctx = Ctx { keys: &keys, text, n: *pos + 1 };
                policies[l].on_token(&ctx, *pos);
            }
            *pos += 1;
        }
        self.busy(self.sim.decode_us_per_step);
        Ok(toks)
    }

    fn estimate_seq_bytes(&self, n_tokens: usize) -> usize {
        KvCache::estimate_bytes_at(
            self.sim.layers,
            self.sim.heads,
            self.sim.head_dim,
            n_tokens,
            self.cfg.kv.precision,
        )
    }

    fn pool(&self) -> &Arc<PagePool> {
        &self.pool
    }

    #[cfg(any(test, feature = "failpoints"))]
    fn faults_injected(&self) -> u64 {
        self.fault.as_ref().map_or(0, |p| p.injected_total())
    }

    #[cfg(any(test, feature = "failpoints"))]
    fn fault_plan(&self) -> Option<&Arc<crate::util::fault::FaultPlan>> {
        self.fault.as_ref()
    }

    fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        Some(&self.prefix)
    }

    fn max_prompt(&self) -> usize {
        self.sim.max_prompt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_prefill_chunks_and_decodes() {
        let mut cfg = Config::new();
        cfg.serving.prefill_chunk_tokens = 64;
        let eng = SimEngine::new(cfg, SimConfig::default());
        let prompt: Vec<u8> = crate::workloads::trace::prompt_text(300, 1);
        let mut st = eng.begin_prefill(1, &prompt, "lychee").unwrap();
        let mut chunks = 0;
        while eng.prefill_chunk(&mut st).unwrap() == PrefillProgress::Pending {
            chunks += 1;
        }
        assert_eq!(chunks + 1, 300usize.div_ceil(64));
        let mut seq = eng.finish_prefill(st).unwrap();
        assert_eq!(seq.pos, 300);
        assert_eq!(seq.kv.len(), 300);
        let sampling = Sampling::default();
        for _ in 0..5 {
            let mut refs = [&mut seq];
            eng.decode_batch(&mut refs, &sampling).unwrap();
        }
        assert_eq!(seq.pos, 305);
        assert_eq!(seq.generated.len(), 5);
        assert!(eng.pool().bytes_in_use() > 0);
        drop(seq);
        assert_eq!(eng.pool().bytes_in_use(), 0);
    }

    #[test]
    fn sim_decodes_over_quantized_arena() {
        // End-to-end mixed-precision smoke: chunked prefill + decode with
        // an f16/i8 page arena. Policies build their indexes through the
        // widening KeySource path, gathers dequantize on the fly, and
        // admission estimates shrink with the element size.
        for prec in crate::quant::test_precisions() {
            let mut cfg = Config::new();
            cfg.kv.precision = prec;
            cfg.serving.prefill_chunk_tokens = 64;
            let eng = SimEngine::new(cfg, SimConfig::default());
            let prompt: Vec<u8> = crate::workloads::trace::prompt_text(300, 5);
            let mut st = eng.begin_prefill(1, &prompt, "lychee").unwrap();
            while eng.prefill_chunk(&mut st).unwrap() == PrefillProgress::Pending {}
            let mut seq = eng.finish_prefill(st).unwrap();
            assert_eq!(seq.kv.precision(), prec);
            let sampling = Sampling::default();
            for _ in 0..4 {
                let mut refs = [&mut seq];
                eng.decode_batch(&mut refs, &sampling).unwrap();
            }
            assert_eq!(seq.pos, 304);
            let est = eng.estimate_seq_bytes(300);
            let f32_est = crate::kvcache::KvCache::estimate_bytes(2, 2, 8, 300);
            match prec {
                crate::quant::Precision::F32 => assert_eq!(est, f32_est),
                _ => assert!(est < f32_est, "{prec:?} estimate {est} not smaller"),
            }
            assert!(eng.pool().bytes_in_use() > 0);
            drop(seq);
            assert_eq!(eng.pool().bytes_in_use(), 0);
        }
    }

    /// Prefill + probe + decode one request; returns everything that
    /// must match between radix-hit and cold runs: the tokens adopted,
    /// the prefill chunks executed, per-layer retrieval selections for
    /// deterministic probe queries (before and after decode), index
    /// bytes, and the decoded tokens.
    #[allow(clippy::type_complexity)]
    fn run_and_probe(
        eng: &SimEngine,
        prompt: &[u8],
        policy: &str,
        id: u64,
    ) -> (usize, usize, Vec<Vec<usize>>, usize, Vec<u8>) {
        let mut st = eng.begin_prefill(id, prompt, policy).unwrap();
        let reused = st.prefix_tokens_reused();
        while eng.prefill_chunk(&mut st).unwrap() == PrefillProgress::Pending {}
        let chunks = st.chunks_executed();
        let mut seq = eng.finish_prefill(st).unwrap();
        let probe = |seq: &mut Sequence| {
            let n = seq.pos;
            let mut out = Vec::new();
            let Sequence { kv, policies, text, .. } = seq;
            for pi in 0..3u64 {
                let q = Rng::new(0x9_0B0 + pi).normal_vec(kv.row_dim());
                for (l, p) in policies.iter_mut().enumerate() {
                    let keys = LayerKeys { cache: kv, layer: l, n };
                    let ctx = Ctx { keys: &keys, text, n };
                    out.push(p.select(&ctx, &q, n));
                }
            }
            out
        };
        let mut sels = probe(&mut seq);
        let sampling = Sampling::default();
        let mut decoded = Vec::new();
        for _ in 0..3 {
            let mut refs = [&mut seq];
            decoded.extend(eng.decode_batch(&mut refs, &sampling).unwrap());
        }
        sels.extend(probe(&mut seq));
        let bytes = seq.index_bytes();
        (reused, chunks, sels, bytes, decoded)
    }

    /// The tentpole acceptance property: a radix-hit prefill must be
    /// **byte-identical** to a cold one — same retrieval selections
    /// (before and during decode), same index footprint, same decode
    /// stream — across every registered policy, at f32 and over the
    /// quantized-mirror legs, while actually skipping the matched
    /// chunks.
    #[test]
    fn radix_hit_prefill_is_byte_identical_to_cold() {
        let prompt = crate::workloads::trace::prompt_text(520, 11);
        let expect_reuse = (prompt.len() - 1) / crate::kvcache::PAGE_SIZE
            * crate::kvcache::PAGE_SIZE;
        for prec in crate::quant::test_precisions() {
            // full registry on the f32 leg; the quantized legs focus on
            // the policies with real index structure (the rest share
            // the default rebuild path already covered at f32)
            let roster: Vec<&str> = if prec == crate::quant::Precision::F32 {
                crate::sparse::POLICY_NAMES.to_vec()
            } else {
                vec!["lychee", "sentencekv", "quest", "arkvale", "shadowkv", "clusterkv"]
            };
            for policy in roster {
                let mut cfg = Config::new();
                cfg.kv.prefix_cache_mb = 64;
                cfg.lychee.rep_precision = prec;
                cfg.lychee.budget = 192;
                cfg.lychee.sink = 8;
                cfg.lychee.recent = 16;
                cfg.serving.prefill_chunk_tokens = 96;
                let eng = SimEngine::new(cfg.clone(), SimConfig::default());
                let mut off_cfg = cfg.clone();
                off_cfg.kv.prefix_cache_mb = 0;
                let eng_off = SimEngine::new(off_cfg, SimConfig::default());

                let cold = run_and_probe(&eng, &prompt, policy, 1);
                let hit = run_and_probe(&eng, &prompt, policy, 2);
                let reference = run_and_probe(&eng_off, &prompt, policy, 3);

                assert_eq!(cold.0, 0, "{policy}@{prec:?}: first run must be cold");
                assert_eq!(
                    hit.0, expect_reuse,
                    "{policy}@{prec:?}: second run must adopt the sealed prefix"
                );
                assert!(
                    hit.1 < cold.1,
                    "{policy}@{prec:?}: radix hit did not skip chunks ({} vs {})",
                    hit.1,
                    cold.1
                );
                assert_eq!(cold.2, hit.2, "{policy}@{prec:?}: selections diverged on hit");
                assert_eq!(cold.2, reference.2, "{policy}@{prec:?}: radix-on cold != radix-off");
                assert_eq!(cold.3, hit.3, "{policy}@{prec:?}: index bytes diverged");
                assert_eq!(cold.4, hit.4, "{policy}@{prec:?}: decode stream diverged");
            }
        }
    }

    #[test]
    fn radix_chained_turns_reuse_grows_with_history() {
        // multi-turn shape: each turn's prompt extends the previous
        // turn's prompt + decoded reply; reuse should cover everything
        // but the newest turn's tail
        let mut cfg = Config::new();
        cfg.kv.prefix_cache_mb = 64;
        cfg.serving.prefill_chunk_tokens = 64;
        let eng = SimEngine::new(cfg, SimConfig::default());
        let sampling = Sampling::default();
        let mut history = crate::workloads::trace::prompt_text(400, 3);
        let mut last_reuse = 0usize;
        for turn in 0..3 {
            let mut st = eng.begin_prefill(10 + turn, &history, "lychee").unwrap();
            let reused = st.prefix_tokens_reused();
            if turn > 0 {
                assert!(reused > last_reuse, "turn {turn}: reuse did not grow ({reused})");
                assert_eq!(reused % crate::kvcache::PAGE_SIZE, 0, "reuse not page-aligned");
            }
            last_reuse = reused;
            while eng.prefill_chunk(&mut st).unwrap() == PrefillProgress::Pending {}
            let mut seq = eng.finish_prefill(st).unwrap();
            for _ in 0..5 {
                let mut refs = [&mut seq];
                eng.decode_batch(&mut refs, &sampling).unwrap();
            }
            history = seq.text.clone(); // prompt + reply becomes next prefix
            history.extend(crate::workloads::trace::prompt_text(150, 40 + turn));
            drop(seq);
        }
        assert_eq!(eng.pool().bytes_in_use(), 0, "private pages leaked across turns");
        let st = eng.prefix_cache().unwrap().stats();
        assert!(st.hits >= 2 && st.tokens_reused_total > 0);
        assert_eq!(eng.pool().bytes_shared(), {
            // every shared byte is attributable to the radix cache once
            // all sequences have dropped
            let cache_pages_bytes: usize = st.nodes
                * 2 // K+V
                * 2 // layers
                * crate::kvcache::PagePool::page_bytes(16);
            cache_pages_bytes
        });
    }

    #[test]
    fn sim_chunked_prefill_selects_identically_to_monolithic() {
        // end-to-end variant of the policy-level property: same prompt,
        // chunked vs monolithic sim prefill, identical decode streams
        // and identical retrieval state (index bytes) afterwards
        for policy in ["lychee", "quest", "clusterkv", "arkvale", "shadowkv", "h2o"] {
            let mut mono_cfg = Config::new();
            mono_cfg.serving.prefill_chunk_tokens = 0;
            let mut chunk_cfg = Config::new();
            chunk_cfg.serving.prefill_chunk_tokens = 37;
            let mono_eng = SimEngine::new(mono_cfg, SimConfig::default());
            let chunk_eng = SimEngine::new(chunk_cfg, SimConfig::default());
            let prompt = crate::workloads::trace::prompt_text(2000, 7);
            let sampling = Sampling::default();

            let mut mono_st = mono_eng.begin_prefill(9, &prompt, policy).unwrap();
            assert_eq!(mono_eng.prefill_chunk(&mut mono_st).unwrap(), PrefillProgress::Ready);
            let mut mono = mono_eng.finish_prefill(mono_st).unwrap();

            let mut st = chunk_eng.begin_prefill(9, &prompt, policy).unwrap();
            while chunk_eng.prefill_chunk(&mut st).unwrap() == PrefillProgress::Pending {}
            let mut chunked = chunk_eng.finish_prefill(st).unwrap();

            assert_eq!(chunked.index_bytes(), mono.index_bytes(), "{policy}: index diverged");
            for step in 0..4 {
                let ta = mono_eng.decode_batch(&mut [&mut mono], &sampling).unwrap();
                let tb = chunk_eng.decode_batch(&mut [&mut chunked], &sampling).unwrap();
                assert_eq!(ta, tb, "{policy}: decode diverged at step {step}");
            }
        }
    }
}
