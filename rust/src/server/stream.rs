//! Incremental UTF-8-safe streaming deltas (the `TokenOutputStream`
//! idiom): the engine emits *bytes*, one per decode step, but a stream
//! write must never split a multibyte character across two deltas — a
//! client rendering each delta as it arrives would show replacement
//! garbage for every CJK/emoji character.
//!
//! [`Utf8Stream`] buffers undecodable tails: push a byte, get back
//! `Some(delta)` only once the buffered bytes form complete characters.
//! Invalid sequences degrade to U+FFFD exactly like the previous
//! per-byte `from_utf8_lossy` path, so pure-ASCII token streams (the sim
//! engine's entire vocabulary) are byte-identical to pre-stream
//! behavior.

/// Incremental UTF-8 decoder over a byte-at-a-time token stream.
#[derive(Default)]
pub struct Utf8Stream {
    /// Undecoded tail: at most 3 bytes of an incomplete character.
    buf: Vec<u8>,
}

impl Utf8Stream {
    pub fn new() -> Utf8Stream {
        Utf8Stream { buf: Vec::new() }
    }

    /// Feed one token byte; returns the newly-decodable text, if any.
    /// Complete characters (and U+FFFD for invalid bytes) are emitted as
    /// soon as they close; an incomplete multibyte prefix stays buffered
    /// for the next push.
    pub fn push(&mut self, b: u8) -> Option<String> {
        self.buf.push(b);
        let out = self.drain_decodable();
        if out.is_empty() {
            None
        } else {
            Some(out)
        }
    }

    /// Flush the remaining tail at end of stream: an unfinished multibyte
    /// prefix can never complete, so it degrades to replacement
    /// characters (lossy semantics, matching `String::from_utf8_lossy`).
    pub fn flush(&mut self) -> Option<String> {
        if self.buf.is_empty() {
            return None;
        }
        let out = String::from_utf8_lossy(&self.buf).into_owned();
        self.buf.clear();
        Some(out)
    }

    /// Decode and drain every complete character currently buffered,
    /// replacing definitively-invalid bytes, keeping an incomplete tail.
    fn drain_decodable(&mut self) -> String {
        let mut out = String::new();
        loop {
            match std::str::from_utf8(&self.buf) {
                Ok(s) => {
                    out.push_str(s);
                    self.buf.clear();
                    break;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    if let Ok(s) = std::str::from_utf8(&self.buf[..valid]) {
                        out.push_str(s);
                    }
                    match e.error_len() {
                        // incomplete trailing sequence: may still close
                        None => {
                            self.buf.drain(..valid);
                            break;
                        }
                        // definitively invalid bytes: one U+FFFD per
                        // byte, mirroring the old per-byte lossy path
                        Some(n) => {
                            for _ in 0..n {
                                out.push('\u{FFFD}');
                            }
                            self.buf.drain(..valid + n);
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(s: &mut Utf8Stream, bytes: &[u8]) -> Vec<Option<String>> {
        bytes.iter().map(|&b| s.push(b)).collect()
    }

    #[test]
    fn ascii_is_emitted_per_byte() {
        let mut s = Utf8Stream::new();
        let out = feed(&mut s, b"hi!");
        assert_eq!(
            out,
            vec![Some("h".into()), Some("i".into()), Some("!".into())]
        );
        assert_eq!(s.flush(), None);
    }

    /// The satellite case: a tokenizer that splits a multibyte char
    /// across token boundaries must not split the stream write.
    #[test]
    fn split_multibyte_chars_emit_once_complete() {
        // "é" (2 bytes), "中" (3 bytes), "🦀" (4 bytes)
        let mut s = Utf8Stream::new();
        assert_eq!(feed(&mut s, "é".as_bytes()), vec![None, Some("é".into())]);
        assert_eq!(
            feed(&mut s, "中".as_bytes()),
            vec![None, None, Some("中".into())]
        );
        assert_eq!(
            feed(&mut s, "🦀".as_bytes()),
            vec![None, None, None, Some("🦀".into())]
        );
        assert_eq!(s.flush(), None);
    }

    #[test]
    fn mixed_ascii_and_multibyte_stream() {
        let mut s = Utf8Stream::new();
        let text = "a中b";
        let mut got = String::new();
        for &b in text.as_bytes() {
            if let Some(d) = s.push(b) {
                got.push_str(&d);
            }
        }
        assert_eq!(got, text);
    }

    #[test]
    fn invalid_bytes_degrade_to_replacement_chars() {
        let mut s = Utf8Stream::new();
        // 0xFF can never start a sequence: replaced immediately
        assert_eq!(s.push(0xFF), Some("\u{FFFD}".to_string()));
        // a continuation byte with no lead byte is also invalid
        assert_eq!(s.push(0x80), Some("\u{FFFD}".to_string()));
        // an aborted 3-byte sequence followed by ASCII: the lead+cont
        // bytes are invalidated by the ASCII byte and replaced
        assert_eq!(s.push(0xE4), None);
        assert_eq!(s.push(0xB8), None);
        let d = s.push(b'x');
        assert_eq!(d, Some("\u{FFFD}\u{FFFD}x".to_string()));
    }

    #[test]
    fn flush_replaces_truncated_tail() {
        let mut s = Utf8Stream::new();
        // first two bytes of "中", never completed
        assert_eq!(s.push(0xE4), None);
        assert_eq!(s.push(0xB8), None);
        assert_eq!(s.flush(), Some("\u{FFFD}".to_string()));
        // flush on a clean stream is a no-op
        assert_eq!(s.flush(), None);
    }
}
