//! TCP JSON-lines serving front-end over the coordinator.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"prompt": "...", "max_new_tokens": 32, "policy": "lychee"}
//! <- {"token": "t"}            (streamed, one per generated token)
//! <- {"done": true, "request_id": 7, "tokens": 32, "ttft_ms": ...,
//!     "tpot_ms": ...}
//! or {"error": "..."}
//!
//! -> {"metrics": true}
//! <- {"requests": ..., "completed": ..., "prefill_chunks_executed": ...,
//!     "preemptions": ..., "prefix_hits": ..., "queue_depth": ..., ...}
//!
//! -> {"cancel": 7}          (best-effort: ack means delivered, not found)
//! <- {"ok": true, "cancel": 7}
//!
//! -> {"drain": true}        (admin: stop admission, finish running work)
//! <- {"ok": true, "drain": true}
//! ```
//!
//! A request may carry `"deadline_ms": <n>` — a per-request wall-clock
//! budget enforced by the scheduler every tick (0 disables the
//! configured `serving.default_deadline_ms`). A request that is
//! cancelled or deadline-expired terminates with
//! `{"cancelled": true, "request_id": N, "reason": "cancelled" |
//! "deadline_exceeded"}` instead of a `done` line. If a stream write
//! fails (client disconnected mid-stream), the server cancels the
//! request coordinator-side so it stops consuming KV pages, and — for
//! session turns — does **not** record the turn the client never
//! received.
//!
//! Multi-turn sessions: a request may carry `"session_id": "s1"` and
//! (after the first turn) `"parent": <request_id of the previous turn>`.
//! The server keeps each session's accumulated text (prompt + generated
//! replies) and prepends it to the new turn's `prompt`, so chained
//! clients send only the incremental turn while the engine sees the full
//! conversation — whose prefix the radix cache then reuses. A `parent`
//! that does not match the session's last request id is rejected (the
//! client raced another turn). Anonymous requests (no `session_id`)
//! still benefit from content-based radix matching.
//!
//! Two connection fronts drive this protocol (`serving.frontend`): the
//! legacy thread-per-connection loop (`threads`, the default —
//! byte-identical wire behavior to prior releases) and the event-driven
//! epoll reactor (`epoll`, see [`net::reactor`]) that owns every client
//! socket on one thread, speaks HTTP/1.1 + SSE alongside the line
//! protocol on the same listener, and couples accept/write backpressure
//! to the coordinator queue depth. Both fronts build replies from the
//! same JSON helpers below, so the line protocol is identical either
//! way; the coordinator handle is cloneable and thread-safe.

use crate::coordinator::cluster::Cluster;
use crate::coordinator::{CancelKind, Event, FinishStats, Handle, Metrics, Notify, Request};
use crate::util::json::Json;
use crate::util::lock_recover;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};

mod http;
#[cfg(unix)]
pub mod mux;
#[cfg(unix)]
pub mod net;
mod stream;

use stream::Utf8Stream;

/// What the connection handler needs from the serving tier, so the same
/// protocol loop runs over a single coordinator or the sharded cluster
/// router: submit/cancel/drain semantics are identical, only the metrics
/// scrape shape differs (flat vs per-shard + aggregate).
pub(crate) trait Gateway: Send + Sync {
    /// Submit with an optional per-event wakeup hook: an event-loop
    /// front passes `Some(waker)` so token arrival interrupts its poll
    /// wait; blocking fronts pass `None` and get plain channel
    /// semantics, byte for byte.
    fn submit_with_notify(&self, req: Request, notify: Option<Notify>)
        -> Result<Receiver<Event>>;
    fn cancel(&self, request_id: u64);
    fn drain(&self);
    /// `None` = metrics not enabled on this server.
    fn metrics_scrape(&self) -> Option<Json>;
    /// Current coordinator pending depth (summed across shards), for
    /// queue-coupled accept gating in the reactor front.
    fn queue_depth(&self) -> u64;
    /// The [`Metrics`] cell where the serving front publishes its own
    /// gauges (`connections_open`, `accepts_deferred`, ...); `None` when
    /// metrics are disabled.
    fn front_cell(&self) -> Option<Arc<Mutex<Metrics>>>;
}

/// Single-coordinator tier: the pre-cluster behavior, byte for byte.
struct SingleGateway {
    handle: Handle,
    metrics: Option<Arc<Mutex<Metrics>>>,
}

impl Gateway for SingleGateway {
    fn submit_with_notify(
        &self,
        req: Request,
        notify: Option<Notify>,
    ) -> Result<Receiver<Event>> {
        self.handle.submit_with_notify(req, notify)
    }
    fn cancel(&self, request_id: u64) {
        self.handle.cancel(request_id);
    }
    fn drain(&self) {
        self.handle.drain();
    }
    fn metrics_scrape(&self) -> Option<Json> {
        self.metrics.as_ref().map(|m| metrics_json(&lock_recover(m)))
    }
    fn queue_depth(&self) -> u64 {
        self.metrics.as_ref().map(|m| lock_recover(m).queue_depth).unwrap_or(0)
    }
    fn front_cell(&self) -> Option<Arc<Mutex<Metrics>>> {
        self.metrics.clone()
    }
}

impl Gateway for Cluster {
    fn submit_with_notify(
        &self,
        req: Request,
        notify: Option<Notify>,
    ) -> Result<Receiver<Event>> {
        Cluster::submit_with_notify(self, req, notify)
    }
    fn cancel(&self, request_id: u64) {
        Cluster::cancel(self, request_id);
    }
    fn drain(&self) {
        // fans out: admission closes on every shard, in-flight work
        // finishes everywhere, aggregate drain_state reaches 2 last
        Cluster::drain(self);
    }
    fn metrics_scrape(&self) -> Option<Json> {
        Some(cluster_metrics_json(self))
    }
    fn queue_depth(&self) -> u64 {
        Cluster::queue_depth(self)
    }
    fn front_cell(&self) -> Option<Arc<Mutex<Metrics>>> {
        Some(self.front_metrics())
    }
}

/// Per-session chaining state: the accumulated conversation text and the
/// request id of the last completed turn (what the next `parent` must
/// reference).
struct SessionState {
    last_id: u64,
    text: Vec<u8>,
    /// Monotonic touch tick for LRU eviction.
    touched: u64,
}

/// Server-wide session store, shared across connections so a session can
/// reconnect. LRU-bounded at `serving.session_store_cap` entries (default
/// 1024). Bounding matters under session churn: a stale (evicted) session
/// can always be resumed as a fresh one — the first turn of a session
/// never carries `parent` — and the radix cache still content-matches the
/// resent history.
pub(crate) struct SessionStore {
    map: HashMap<String, SessionState>,
    tick: u64,
    cap: usize,
}

impl SessionStore {
    pub(crate) fn new(cap: usize) -> SessionStore {
        // a zero cap would evict every session the moment it is recorded,
        // turning every second turn into a `session_unknown` error;
        // config validation rejects it, this is belt and braces
        SessionStore { map: HashMap::new(), tick: 0, cap: cap.max(1) }
    }
    /// Accumulated text + last request id for a session, refreshing its
    /// LRU slot.
    fn touch(&mut self, sid: &str) -> Option<(u64, Vec<u8>)> {
        self.tick += 1;
        let tick = self.tick;
        let st = self.map.get_mut(sid)?;
        st.touched = tick;
        Some((st.last_id, st.text.clone()))
    }

    /// Record a completed turn, evicting the LRU session past the cap.
    fn update(&mut self, sid: &str, last_id: u64, text: Vec<u8>) {
        self.tick += 1;
        let touched = self.tick;
        self.map.insert(sid.to_string(), SessionState { last_id, text, touched });
        if self.map.len() > self.cap {
            if let Some(oldest) =
                self.map.iter().min_by_key(|(_, s)| s.touched).map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
    }
}

pub(crate) type Sessions = Arc<Mutex<SessionStore>>;

/// A running TCP server; dropping stops accepting (in-flight requests
/// finish on the coordinator).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

/// Default [`SessionStore`] bound when the caller does not plumb a
/// config through ([`Server::start`]); mirrors the
/// `serving.session_store_cap` default.
const DEFAULT_SESSION_CAP: usize = 1024;

/// Connection-front selection and backpressure knobs, resolved from
/// `serving.*` config (`frontend`, `session_store_cap`,
/// `write_high_water_bytes`, `shed_watermark`).
#[derive(Clone, Copy)]
pub struct FrontOptions {
    pub frontend: crate::config::Frontend,
    pub session_cap: usize,
    /// Per-connection write-queue high-water mark in bytes (reactor
    /// front): past this the reactor stops pulling coordinator events
    /// for the connection until the socket drains. 0 = unbounded.
    pub write_high_water: usize,
    /// Coordinator queue depth at which the reactor pauses `accept`
    /// (mirrors `serving.shed_watermark`; 0 = never pause).
    pub shed_watermark: usize,
}

impl FrontOptions {
    pub fn from_serving(s: &crate::config::ServingConfig) -> FrontOptions {
        FrontOptions {
            frontend: s.frontend,
            session_cap: s.session_store_cap,
            write_high_water: s.write_high_water_bytes,
            shed_watermark: s.shed_watermark,
        }
    }

    /// The legacy front with default knobs (pre-`frontend` callers).
    fn threads(session_cap: usize) -> FrontOptions {
        FrontOptions {
            frontend: crate::config::Frontend::Threads,
            session_cap,
            write_high_water: 0,
            shed_watermark: 0,
        }
    }
}

impl Server {
    /// Bind and start serving on `addr` (use port 0 for an OS-assigned
    /// port; the bound address is in `server.addr`). Pass the
    /// coordinator's shared [`Metrics`] to enable the `{"metrics": true}`
    /// scrape request. Session store bound = the default cap; use
    /// [`Server::start_single`] to plumb `serving.session_store_cap`.
    pub fn start(
        addr: &str,
        handle: Handle,
        metrics: Option<Arc<Mutex<Metrics>>>,
    ) -> Result<Server> {
        Self::start_single(addr, handle, metrics, DEFAULT_SESSION_CAP)
    }

    /// [`Server::start`] with an explicit session-store LRU bound
    /// (`serving.session_store_cap`).
    pub fn start_single(
        addr: &str,
        handle: Handle,
        metrics: Option<Arc<Mutex<Metrics>>>,
        session_cap: usize,
    ) -> Result<Server> {
        Self::start_gateway(
            addr,
            Arc::new(SingleGateway { handle, metrics }),
            FrontOptions::threads(session_cap),
        )
    }

    /// [`Server::start_single`] with the connection front selected by
    /// `serving.frontend` (`threads` | `epoll`) and the reactor's
    /// backpressure knobs plumbed through.
    pub fn start_single_with(
        addr: &str,
        handle: Handle,
        metrics: Option<Arc<Mutex<Metrics>>>,
        serving: &crate::config::ServingConfig,
    ) -> Result<Server> {
        Self::start_gateway(
            addr,
            Arc::new(SingleGateway { handle, metrics }),
            FrontOptions::from_serving(serving),
        )
    }

    /// Serve over a sharded [`Cluster`]: same wire protocol, but submit
    /// routes through the consistent-hash router, `{"drain": true}` fans
    /// out to every shard, and `{"metrics": true}` reports per-shard and
    /// aggregated gauges plus the router counters.
    pub fn start_cluster(addr: &str, cluster: Cluster, session_cap: usize) -> Result<Server> {
        Self::start_gateway(addr, Arc::new(cluster), FrontOptions::threads(session_cap))
    }

    /// [`Server::start_cluster`] with the front selected by
    /// `serving.frontend`.
    pub fn start_cluster_with(
        addr: &str,
        cluster: Cluster,
        serving: &crate::config::ServingConfig,
    ) -> Result<Server> {
        Self::start_gateway(addr, Arc::new(cluster), FrontOptions::from_serving(serving))
    }

    fn start_gateway(
        addr: &str,
        gateway: Arc<dyn Gateway>,
        opts: FrontOptions,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        // the epoll front: one reactor thread owns every client socket
        // (non-unix builds have no epoll/poll bindings and fall back to
        // the threads front)
        #[cfg(unix)]
        if opts.frontend == crate::config::Frontend::Epoll {
            let stop2 = Arc::clone(&stop);
            let ropts = net::reactor::ReactorOptions {
                session_cap: opts.session_cap,
                write_high_water: opts.write_high_water,
                shed_watermark: opts.shed_watermark,
            };
            let accept_thread = std::thread::Builder::new()
                .name("lychee-reactor".into())
                .spawn(move || {
                    let _ = net::reactor::run(listener, gateway, stop2, ropts);
                })?;
            return Ok(Server { addr: local, stop, accept_thread: Some(accept_thread) });
        }
        // the threads front: legacy accept loop, byte-identical wire
        // behavior to prior releases
        let stop2 = Arc::clone(&stop);
        let next_id = Arc::new(AtomicU64::new(1));
        let sessions: Sessions = Arc::new(Mutex::new(SessionStore::new(opts.session_cap)));
        let accept_thread = std::thread::Builder::new()
            .name("lychee-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let g = Arc::clone(&gateway);
                            let ids = Arc::clone(&next_id);
                            let s = Arc::clone(&sessions);
                            std::thread::spawn(move || {
                                let _gauge = ConnGauge::new(g.front_cell());
                                let _ = handle_conn(stream, g, &ids, s);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Default output length when a request omits `max_new_tokens` (the
/// coordinator additionally clamps to `serving.max_new_tokens`).
pub const DEFAULT_MAX_NEW_TOKENS: usize = 32;

/// Validated fields of one JSON-lines request.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    pub prompt: Vec<u8>,
    pub max_new_tokens: Option<usize>,
    pub policy: String,
    /// Multi-turn session key: the server prepends the session's
    /// accumulated text to `prompt` (see module docs).
    pub session_id: Option<String>,
    /// Request id of the session's previous turn; validated against the
    /// session head when present.
    pub parent: Option<u64>,
    /// Per-request deadline in milliseconds, enforced by the scheduler.
    /// `Some(0)` explicitly disables `serving.default_deadline_ms`;
    /// `None` inherits it.
    pub deadline_ms: Option<u64>,
}

/// Validate a wire request before it reaches the scheduler: a missing
/// prompt, `max_new_tokens: 0` (a no-op the old code happily enqueued),
/// non-integer token counts, and unknown policies all get a structured
/// `{"error": ...}` reply instead of a panic or a wasted prefill.
/// Absurdly large `max_new_tokens` are accepted here and clamped by the
/// coordinator to its configured `serving.max_new_tokens` cap.
pub fn parse_request(j: &Json) -> std::result::Result<WireRequest, String> {
    let Some(prompt) = j.get("prompt").as_str() else {
        return Err("missing 'prompt'".to_string());
    };
    let max_new_tokens = match j.get("max_new_tokens") {
        Json::Null => None,
        v => {
            let Some(n) = v.as_f64() else {
                return Err("'max_new_tokens' must be an integer".to_string());
            };
            if n.fract() != 0.0 || n < 0.0 {
                return Err("'max_new_tokens' must be a non-negative integer".to_string());
            }
            if n == 0.0 {
                return Err("'max_new_tokens' must be >= 1".to_string());
            }
            Some(n as usize)
        }
    };
    let policy = match j.get("policy") {
        Json::Null => "lychee".to_string(),
        v => match v.as_str() {
            Some(p) if crate::sparse::POLICY_NAMES.contains(&p) => p.to_string(),
            Some(p) => {
                return Err(format!(
                    "unknown policy '{p}' (valid: {})",
                    crate::sparse::POLICY_NAMES.join(", ")
                ))
            }
            None => return Err("'policy' must be a string".to_string()),
        },
    };
    let session_id = match j.get("session_id") {
        Json::Null => None,
        v => match v.as_str() {
            Some(s) if !s.is_empty() => Some(s.to_string()),
            Some(_) => return Err("'session_id' must be non-empty".to_string()),
            None => return Err("'session_id' must be a string".to_string()),
        },
    };
    let parent = match j.get("parent") {
        Json::Null => None,
        v => {
            let Some(n) = v.as_f64() else {
                return Err("'parent' must be a request id".to_string());
            };
            if n.fract() != 0.0 || n < 0.0 {
                return Err("'parent' must be a request id".to_string());
            }
            if session_id.is_none() {
                return Err("'parent' requires 'session_id'".to_string());
            }
            Some(n as u64)
        }
    };
    let deadline_ms = match j.get("deadline_ms") {
        Json::Null => None,
        v => {
            let Some(n) = v.as_f64() else {
                return Err("'deadline_ms' must be a non-negative integer".to_string());
            };
            if n.fract() != 0.0 || n < 0.0 {
                return Err("'deadline_ms' must be a non-negative integer".to_string());
            }
            Some(n as u64)
        }
    };
    Ok(WireRequest {
        prompt: prompt.as_bytes().to_vec(),
        max_new_tokens,
        policy,
        session_id,
        parent,
        deadline_ms,
    })
}

/// Render the serving metrics as one JSON reply line.
fn metrics_json(m: &Metrics) -> Json {
    Json::obj(metrics_fields(m))
}

/// Cluster scrape: the aggregate gauges at the top level (same keys as
/// the single-coordinator scrape, so dashboards keep working), plus a
/// `"shards"` array with each shard's full gauge set and health, and a
/// `"router"` object with the routing-front counters.
fn cluster_metrics_json(cluster: &Cluster) -> Json {
    let mut fields = metrics_fields(&cluster.aggregate_metrics());
    let shards: Vec<Json> = (0..cluster.shard_count())
        .map(|i| {
            let m = cluster.shard_metrics(i);
            let mut f = vec![
                ("shard", Json::num(i as f64)),
                ("alive", Json::Bool(cluster.shard_alive(i))),
                ("heartbeat_ticks", Json::num(cluster.shard_heartbeat_ticks(i) as f64)),
            ];
            f.extend(metrics_fields(&lock_recover(&m)));
            Json::obj(f)
        })
        .collect();
    fields.push(("shards", Json::Arr(shards)));
    let r = cluster.router_snapshot();
    fields.push((
        "router",
        Json::obj(vec![
            ("routed_total", Json::num(r.routed_total as f64)),
            ("failovers_total", Json::num(r.failovers_total as f64)),
            ("shed_retries_total", Json::num(r.shed_retries_total as f64)),
            ("stall_quarantines_total", Json::num(r.stall_quarantines_total as f64)),
        ]),
    ));
    Json::obj(fields)
}

/// The flat key/value set of one [`Metrics`] cell (shared between the
/// single scrape, the cluster aggregate, and the per-shard entries).
fn metrics_fields(m: &Metrics) -> Vec<(&'static str, Json)> {
    vec![
        ("requests", Json::num(m.requests as f64)),
        ("completed", Json::num(m.completed as f64)),
        ("rejected", Json::num(m.rejected as f64)),
        ("tokens_out", Json::num(m.tokens_out as f64)),
        ("kv_bytes_in_use", Json::num(m.kv_bytes_in_use as f64)),
        ("kv_bytes_free", Json::num(m.kv_bytes_free as f64)),
        ("kv_bytes_free_peak", Json::num(m.kv_bytes_free_peak as f64)),
        ("kv_pages_recycled_total", Json::num(m.kv_pages_recycled_total as f64)),
        ("kv_precision", Json::str(&m.kv_precision)),
        ("rep_precision", Json::str(&m.rep_precision)),
        ("admission_waits", Json::num(m.admission_waits as f64)),
        ("prefill_chunks_executed", Json::num(m.prefill_chunks_executed as f64)),
        ("preemptions", Json::num(m.preemptions as f64)),
        ("prefix_hits", Json::num(m.prefix_hits as f64)),
        ("prefix_tokens_reused", Json::num(m.prefix_tokens_reused as f64)),
        ("prefix_evictions", Json::num(m.prefix_evictions as f64)),
        ("kv_bytes_shared", Json::num(m.kv_bytes_shared as f64)),
        ("selects_before_build", Json::num(m.selects_before_build as f64)),
        ("blocks_scanned_total", Json::num(m.blocks_scanned_total as f64)),
        ("blocks_pruned_total", Json::num(m.blocks_pruned_total as f64)),
        ("queue_depth", Json::num(m.queue_depth as f64)),
        ("requests_in_flight", Json::num(m.requests_in_flight as f64)),
        ("cancellations", Json::num(m.cancellations as f64)),
        ("deadline_exceeded", Json::num(m.deadline_exceeded as f64)),
        ("sequence_panics", Json::num(m.sequence_panics as f64)),
        ("faults_injected_total", Json::num(m.faults_injected_total as f64)),
        ("drain_state", Json::num(m.drain_state as f64)),
        ("sheds", Json::num(m.sheds as f64)),
        ("connections_open", Json::num(m.connections_open as f64)),
        ("accepts_deferred", Json::num(m.accepts_deferred as f64)),
        ("reactor_wakeups_total", Json::num(m.reactor_wakeups_total as f64)),
        ("write_queue_high_water", Json::num(m.write_queue_high_water as f64)),
        ("ttft_p50_us", Json::num(m.ttft_us.quantile(0.5))),
        ("ttft_p99_us", Json::num(m.ttft_us.quantile(0.99))),
        ("ttft_mean_us", Json::num(m.ttft_us.mean())),
        ("tpot_p50_us", Json::num(m.tpot_us.quantile(0.5))),
        ("tpot_p99_us", Json::num(m.tpot_us.quantile(0.99))),
        ("tpot_mean_us", Json::num(m.tpot_us.mean())),
    ]
}

// ---------------------------------------------------------------------
// Shared protocol pieces: both fronts (threads + reactor) build every
// reply from these, so the wire format cannot drift between them.
// ---------------------------------------------------------------------

/// `{"error": msg}`.
pub(crate) fn err_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg))])
}

/// `{"error": msg, "code": code}` — structured error with a
/// machine-readable `code` (the session protocol needs clients to tell
/// a retryable condition from a protocol bug without string-matching
/// the message).
pub(crate) fn err_code_json(code: &str, msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg)), ("code", Json::str(code))])
}

/// One streamed token delta.
pub(crate) fn token_json(delta: &str) -> Json {
    Json::obj(vec![("token", Json::str(delta))])
}

/// The terminal `done` line.
pub(crate) fn done_json(request_id: u64, stats: &FinishStats) -> Json {
    Json::obj(vec![
        ("done", Json::Bool(true)),
        ("request_id", Json::num(request_id as f64)),
        ("tokens", Json::num(stats.tokens as f64)),
        ("ttft_ms", Json::num(stats.ttft_ms)),
        ("tpot_ms", Json::num(stats.tpot_ms)),
        ("e2e_ms", Json::num(stats.e2e_ms)),
    ])
}

/// The terminal `cancelled` line (explicit cancel or deadline).
pub(crate) fn cancelled_json(request_id: u64, kind: CancelKind) -> Json {
    Json::obj(vec![
        ("cancelled", Json::Bool(true)),
        ("request_id", Json::num(request_id as f64)),
        ("reason", Json::str(kind.as_str())),
    ])
}

/// Ack for `{"cancel": id}`.
pub(crate) fn cancel_ack_json(id: f64) -> Json {
    Json::obj(vec![("ok", Json::Bool(true)), ("cancel", Json::num(id))])
}

/// Ack for `{"drain": true}`.
pub(crate) fn drain_ack_json() -> Json {
    Json::obj(vec![("ok", Json::Bool(true)), ("drain", Json::Bool(true))])
}

/// Shed reply text (single tier with a watermark configured; the
/// cluster router absorbs Shed and retries internally).
pub(crate) const SHED_MSG: &str = "request shed: queue over watermark, retry later";

/// Admin verbs a protocol line can carry instead of a generation
/// request, in the order the threads front always checked them.
pub(crate) enum Admin {
    Cancel(u64),
    /// `cancel` present but not a valid request id.
    BadCancel,
    Drain,
    Metrics,
    /// Not an admin line: parse as a generation request.
    None,
}

pub(crate) fn classify_admin(j: &Json) -> Admin {
    match j.get("cancel") {
        Json::Null => {}
        v => {
            return match v.as_f64().filter(|n| n.fract() == 0.0 && *n >= 0.0) {
                Some(n) => Admin::Cancel(n as u64),
                None => Admin::BadCancel,
            }
        }
    }
    if j.get("drain").as_bool() == Some(true) {
        return Admin::Drain;
    }
    if j.get("metrics").as_bool() == Some(true) {
        return Admin::Metrics;
    }
    Admin::None
}

/// Session chaining: the engine-visible prompt (accumulated history +
/// this turn, so the radix cache reuses the sealed prefix), or a
/// structured `(code, message)` protocol error. A `parent` that does
/// not match the session head is a real protocol bug (the client raced
/// another turn, NOT retryable as-is); a `parent` against an unknown
/// session (never seen, or LRU-evicted) is retryable by resending the
/// history as a fresh first turn.
pub(crate) fn resolve_session(
    sessions: &Sessions,
    wire: &WireRequest,
) -> std::result::Result<Vec<u8>, (&'static str, String)> {
    let Some(sid) = &wire.session_id else {
        return Ok(wire.prompt.clone());
    };
    let state = lock_recover(sessions).touch(sid);
    match state {
        Some((head, text)) => {
            if let Some(parent) = wire.parent {
                if parent != head {
                    return Err((
                        "parent_mismatch",
                        format!("parent {parent} does not match session '{sid}' head {head}"),
                    ));
                }
            }
            let mut p = text;
            p.extend_from_slice(&wire.prompt);
            Ok(p)
        }
        None => {
            if wire.parent.is_some() {
                return Err((
                    "session_unknown",
                    format!("'parent' given but session '{sid}' has no prior turn"),
                ));
            }
            Ok(wire.prompt.clone())
        }
    }
}

/// Record a completed session turn: the next turn's prefix = this
/// turn's full prompt + reply.
pub(crate) fn record_turn(
    sessions: &Sessions,
    sid: &str,
    request_id: u64,
    full_prompt: &[u8],
    generated: &[u8],
) {
    let mut text = full_prompt.to_vec();
    text.extend_from_slice(generated);
    lock_recover(sessions).update(sid, request_id, text);
}

/// RAII `connections_open` gauge: one per live connection on the
/// threads front, decremented on every exit path.
struct ConnGauge(Option<Arc<Mutex<Metrics>>>);

impl ConnGauge {
    fn new(cell: Option<Arc<Mutex<Metrics>>>) -> ConnGauge {
        if let Some(m) = &cell {
            lock_recover(m).connections_open += 1;
        }
        ConnGauge(cell)
    }
}

impl Drop for ConnGauge {
    fn drop(&mut self) {
        if let Some(m) = &self.0 {
            let mut g = lock_recover(m);
            g.connections_open = g.connections_open.saturating_sub(1);
        }
    }
}

/// Nonblocking probe for a half-closed peer: a client that went away
/// mid-stream reads as EOF (`Ok(0)`) long before writes start failing
/// (TCP buffers absorb a window's worth of tokens first). Pipelined
/// request bytes read as `Ok(n)` (alive); `WouldBlock` means quiet but
/// connected. Probe failures count as gone: freeing the sequence is
/// the safe direction.
fn peer_gone(stream: &TcpStream) -> bool {
    let mut probe = [0u8; 1];
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let gone = match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    stream.set_nonblocking(false).is_err() || gone
}

fn handle_conn(
    stream: TcpStream,
    gateway: Arc<dyn Gateway>,
    ids: &AtomicU64,
    sessions: Sessions,
) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply_err = |w: &mut TcpStream, msg: &str| -> Result<()> {
            writeln!(w, "{}", err_json(msg).dump())?;
            Ok(())
        };
        let parsed = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                reply_err(&mut writer, &format!("bad json: {e}"))?;
                continue;
            }
        };
        match classify_admin(&parsed) {
            Admin::Cancel(id) => {
                // best-effort: the ack means the cancel was delivered to
                // the scheduler, not that the request was found
                gateway.cancel(id);
                writeln!(writer, "{}", cancel_ack_json(id as f64).dump())?;
                continue;
            }
            Admin::BadCancel => {
                reply_err(&mut writer, "'cancel' must be a request id")?;
                continue;
            }
            Admin::Drain => {
                gateway.drain();
                writeln!(writer, "{}", drain_ack_json().dump())?;
                continue;
            }
            Admin::Metrics => {
                match gateway.metrics_scrape() {
                    Some(j) => writeln!(writer, "{}", j.dump())?,
                    None => reply_err(&mut writer, "metrics not enabled on this server")?,
                }
                continue;
            }
            Admin::None => {}
        }
        let wire = match parse_request(&parsed) {
            Ok(w) => w,
            Err(msg) => {
                reply_err(&mut writer, &msg)?;
                continue;
            }
        };
        let full_prompt = match resolve_session(&sessions, &wire) {
            Ok(p) => p,
            Err((code, msg)) => {
                writeln!(writer, "{}", err_code_json(code, &msg).dump())?;
                continue;
            }
        };
        let req_id = ids.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id: req_id,
            prompt: full_prompt.clone(),
            max_new_tokens: wire.max_new_tokens.unwrap_or(DEFAULT_MAX_NEW_TOKENS),
            policy: wire.policy,
            deadline_ms: wire.deadline_ms,
            carried_tokens: 0,
        };
        let rx = match gateway.submit_with_notify(req, None) {
            Ok(rx) => rx,
            Err(e) => {
                reply_err(&mut writer, &e.to_string())?;
                continue;
            }
        };
        let mut generated: Vec<u8> = Vec::new();
        let mut utf8 = Utf8Stream::new();
        'stream: loop {
            let ev = match rx.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(ev) => ev,
                Err(RecvTimeoutError::Timeout) => {
                    // quiet stream (e.g. a long prefill, no tokens yet):
                    // poll the socket for read-EOF so a vanished client
                    // frees its pages instead of us decoding to a dead
                    // socket until a write finally fails
                    if peer_gone(&writer) {
                        gateway.cancel(req_id);
                        return Ok(());
                    }
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => break 'stream,
            };
            match ev {
                Event::Token(t) => {
                    generated.push(t);
                    // UTF-8-safe deltas: hold partial multibyte chars
                    // until they close (ASCII passes through per byte)
                    let Some(delta) = utf8.push(t) else { continue };
                    // check for a half-closed peer between token writes:
                    // writes land in socket buffers long after the
                    // client is gone, but read-EOF shows up immediately,
                    // and the cancel stops the sequence burning KV pages
                    // and decode steps
                    if peer_gone(&writer)
                        || writeln!(writer, "{}", token_json(&delta).dump()).is_err()
                    {
                        gateway.cancel(req_id);
                        return Ok(());
                    }
                }
                Event::Done(stats) => {
                    // flush a truncated multibyte tail (lossy) before the
                    // terminal so the client's text is complete
                    if let Some(tail) = utf8.flush() {
                        if writeln!(writer, "{}", token_json(&tail).dump()).is_err() {
                            return Ok(());
                        }
                    }
                    // write the done line *before* recording the turn:
                    // a turn the client never received must not become
                    // the session head (the client will retry it, and a
                    // phantom head would reject the retry's `parent`)
                    if writeln!(writer, "{}", done_json(req_id, &stats).dump()).is_err() {
                        return Ok(());
                    }
                    if let Some(sid) = &wire.session_id {
                        record_turn(&sessions, sid, req_id, &full_prompt, &generated);
                    }
                    break 'stream;
                }
                Event::Cancelled(kind) => {
                    // no session update: a cancelled turn has no reply
                    writeln!(writer, "{}", cancelled_json(req_id, kind).dump())?;
                    break 'stream;
                }
                Event::Error(e) => {
                    reply_err(&mut writer, &e)?;
                    break 'stream;
                }
                Event::Shed => {
                    // only reachable on a direct single-coordinator tier
                    // with a shed watermark configured: the cluster
                    // router absorbs Shed and retries internally
                    writeln!(writer, "{}", err_code_json("shed", SHED_MSG).dump())?;
                    break 'stream;
                }
            }
        }
    }
    let _ = peer;
    Ok(())
}

/// Minimal blocking client (tests + examples).
pub struct Client {
    stream: TcpStream,
}

/// One completed generation as seen by the client.
#[derive(Debug, Default)]
pub struct ClientResult {
    pub text: String,
    pub tokens: usize,
    pub ttft_ms: f64,
    pub tpot_ms: f64,
    /// Server-assigned request id (`parent` for the session's next turn).
    pub request_id: u64,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        Ok(Client { stream: TcpStream::connect(addr)? })
    }

    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize, policy: &str) -> Result<ClientResult> {
        self.request(prompt, max_new_tokens, policy, None, None, None)
    }

    /// Like [`Client::generate`] with a per-request wall-clock deadline
    /// in milliseconds (0 disables the server's configured default).
    pub fn generate_with_deadline(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        policy: &str,
        deadline_ms: u64,
    ) -> Result<ClientResult> {
        self.request(prompt, max_new_tokens, policy, None, None, Some(deadline_ms))
    }

    /// Session-chained turn: the server prepends the session's
    /// accumulated text; pass the previous turn's `request_id` as
    /// `parent` to assert correct chaining.
    pub fn generate_in_session(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        policy: &str,
        session_id: &str,
        parent: Option<u64>,
    ) -> Result<ClientResult> {
        self.request(prompt, max_new_tokens, policy, Some(session_id), parent, None)
    }

    fn request(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        policy: &str,
        session_id: Option<&str>,
        parent: Option<u64>,
        deadline_ms: Option<u64>,
    ) -> Result<ClientResult> {
        let mut fields = vec![
            ("prompt", Json::str(prompt)),
            ("max_new_tokens", Json::num(max_new_tokens as f64)),
            ("policy", Json::str(policy)),
        ];
        if let Some(sid) = session_id {
            fields.push(("session_id", Json::str(sid)));
        }
        if let Some(p) = parent {
            fields.push(("parent", Json::num(p as f64)));
        }
        if let Some(d) = deadline_ms {
            fields.push(("deadline_ms", Json::num(d as f64)));
        }
        let req = Json::obj(fields);
        writeln!(self.stream, "{}", req.dump())?;
        let mut out = ClientResult::default();
        let reader = BufReader::new(self.stream.try_clone()?);
        for line in reader.lines() {
            let line = line?;
            let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad reply: {e}"))?;
            if let Some(t) = j.get("token").as_str() {
                out.text.push_str(t);
            } else if j.get("done").as_bool() == Some(true) {
                out.tokens = j.get("tokens").as_usize().unwrap_or(0);
                out.ttft_ms = j.get("ttft_ms").as_f64().unwrap_or(0.0);
                out.tpot_ms = j.get("tpot_ms").as_f64().unwrap_or(0.0);
                out.request_id = j.get("request_id").as_usize().unwrap_or(0) as u64;
                return Ok(out);
            } else if j.get("cancelled").as_bool() == Some(true) {
                let reason = j.get("reason").as_str().unwrap_or("cancelled").to_string();
                let id = j.get("request_id").as_usize().unwrap_or(0);
                anyhow::bail!("request {id}: {reason}");
            } else if let Some(e) = j.get("error").as_str() {
                anyhow::bail!("server error: {e}");
            }
        }
        anyhow::bail!("connection closed mid-stream")
    }

    /// Best-effort cancel of a running request by server-assigned id.
    /// The ack means the cancel was delivered, not that it matched.
    pub fn cancel(&mut self, request_id: u64) -> Result<()> {
        writeln!(
            self.stream,
            "{}",
            Json::obj(vec![("cancel", Json::num(request_id as f64))]).dump()
        )?;
        let mut line = String::new();
        BufReader::new(self.stream.try_clone()?).read_line(&mut line)?;
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad cancel reply: {e}"))?;
        if let Some(e) = j.get("error").as_str() {
            anyhow::bail!("server error: {e}");
        }
        Ok(())
    }

    /// Ask the server's coordinator to drain: stop admitting work,
    /// finish (or deadline out) what is running, then exit its loop.
    pub fn drain(&mut self) -> Result<()> {
        writeln!(self.stream, "{}", Json::obj(vec![("drain", Json::Bool(true))]).dump())?;
        let mut line = String::new();
        BufReader::new(self.stream.try_clone()?).read_line(&mut line)?;
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad drain reply: {e}"))?;
        if let Some(e) = j.get("error").as_str() {
            anyhow::bail!("server error: {e}");
        }
        Ok(())
    }

    /// Scrape the server's metrics (`{"metrics": true}` request).
    pub fn metrics(&mut self) -> Result<Json> {
        writeln!(self.stream, "{}", Json::obj(vec![("metrics", Json::Bool(true))]).dump())?;
        let mut line = String::new();
        BufReader::new(self.stream.try_clone()?).read_line(&mut line)?;
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad metrics reply: {e}"))?;
        if let Some(e) = j.get("error").as_str() {
            anyhow::bail!("server error: {e}");
        }
        Ok(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::spawn;
    

    fn test_config() -> Option<Config> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let mut cfg = Config::new();
        cfg.artifacts_dir = dir.to_str().unwrap().to_string();
        Some(cfg)
    }

    #[test]
    fn tcp_round_trip() {
        let Some(cfg) = test_config() else { return };
        let (handle, m, join) = spawn(cfg).unwrap();
        let server = Server::start("127.0.0.1:0", handle.clone(), Some(m)).unwrap();
        let addr = server.addr;

        let mut client = Client::connect(&addr).unwrap();
        let res = client.generate("tcp serving test!", 4, "lychee").unwrap();
        assert_eq!(res.tokens, 4);
        assert!(!res.text.is_empty());
        assert!(res.tpot_ms >= 0.0);

        // second request on the same connection
        let res2 = client.generate("another one.", 3, "full").unwrap();
        assert_eq!(res2.tokens, 3);

        server.stop();
        handle.shutdown();
        join.join().unwrap();
    }

    /// Server round-trip over the artifact-free sim coordinator: tokens
    /// stream, and the metrics scrape reports the chunked-prefill
    /// counters and latency histograms end to end.
    #[test]
    fn sim_round_trip_streams_and_scrapes_metrics() {
        let mut cfg = crate::config::Config::new();
        cfg.serving.prefill_chunk_tokens = 64;
        let engine_cfg = cfg.clone();
        let (handle, metrics, join) = crate::coordinator::spawn_with(cfg, move || {
            Ok(crate::engine::sim::SimEngine::new(
                engine_cfg,
                crate::engine::sim::SimConfig::default(),
            ))
        })
        .unwrap();
        let server = Server::start("127.0.0.1:0", handle.clone(), Some(metrics)).unwrap();

        let mut client = Client::connect(&server.addr).unwrap();
        let prompt = String::from_utf8(crate::workloads::trace::prompt_text(300, 3)).unwrap();
        let res = client.generate(&prompt, 5, "lychee").unwrap();
        assert_eq!(res.tokens, 5);
        assert!(res.ttft_ms > 0.0);

        // one idle scheduler tick so the queue gauge settles to 0
        std::thread::sleep(std::time::Duration::from_millis(100));
        let m = client.metrics().unwrap();
        assert_eq!(m.get("completed").as_usize(), Some(1));
        assert_eq!(m.get("tokens_out").as_usize(), Some(5));
        // 300-token prompt at 64-token chunks = 5 chunks
        assert_eq!(m.get("prefill_chunks_executed").as_usize(), Some(5));
        assert_eq!(m.get("preemptions").as_usize(), Some(0));
        assert_eq!(m.get("queue_depth").as_usize(), Some(0));
        assert!(m.get("ttft_p50_us").as_f64().unwrap_or(0.0) > 0.0);
        assert!(m.get("tpot_p50_us").as_f64().is_some());
        // pool/precision gauges ride the same scrape
        assert_eq!(m.get("kv_precision").as_str(), Some("f32"));
        assert_eq!(m.get("rep_precision").as_str(), Some("f32"));
        assert!(m.get("kv_bytes_free").as_f64().is_some());
        assert!(m.get("kv_bytes_free_peak").as_f64().is_some());
        assert!(m.get("kv_pages_recycled_total").as_f64().is_some());
        // lifecycle counters ride the same scrape, all quiet here
        assert_eq!(m.get("requests_in_flight").as_usize(), Some(0));
        assert_eq!(m.get("cancellations").as_usize(), Some(0));
        assert_eq!(m.get("deadline_exceeded").as_usize(), Some(0));
        assert_eq!(m.get("sequence_panics").as_usize(), Some(0));
        assert_eq!(m.get("faults_injected_total").as_usize(), Some(0));
        assert_eq!(m.get("drain_state").as_usize(), Some(0));
        // serving-front gauges ride the same scrape; on the threads
        // front the scraping connection itself is the one open conn and
        // no reactor ever runs
        assert_eq!(m.get("connections_open").as_usize(), Some(1));
        assert_eq!(m.get("accepts_deferred").as_usize(), Some(0));
        assert_eq!(m.get("reactor_wakeups_total").as_usize(), Some(0));
        assert_eq!(m.get("write_queue_high_water").as_usize(), Some(0));

        // a server started without metrics answers the scrape with an error
        let server2 = Server::start("127.0.0.1:0", handle.clone(), None).unwrap();
        let mut client2 = Client::connect(&server2.addr).unwrap();
        let err = client2.metrics().unwrap_err().to_string();
        assert!(err.contains("metrics not enabled"), "{err}");

        server2.stop();
        server.stop();
        handle.shutdown();
        join.join().unwrap();
    }

    /// Session-chained turns over the sim coordinator: the server must
    /// concatenate turn prompts, validate `parent`, and the radix cache
    /// must register hits on the chained prefixes.
    #[test]
    fn sim_session_chaining_round_trip() {
        let mut cfg = crate::config::Config::new();
        cfg.serving.prefill_chunk_tokens = 64;
        let engine_cfg = cfg.clone();
        let (handle, metrics, join) = crate::coordinator::spawn_with(cfg, move || {
            Ok(crate::engine::sim::SimEngine::new(
                engine_cfg,
                crate::engine::sim::SimConfig::default(),
            ))
        })
        .unwrap();
        let server = Server::start("127.0.0.1:0", handle.clone(), Some(metrics)).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();

        let turn1 = String::from_utf8(crate::workloads::trace::prompt_text(400, 21)).unwrap();
        let r1 = client.generate_in_session(&turn1, 4, "lychee", "s1", None).unwrap();
        assert_eq!(r1.tokens, 4);
        assert!(r1.request_id > 0);
        // wrong parent is rejected with a structured error
        let err = client
            .generate_in_session("next", 2, "lychee", "s1", Some(r1.request_id + 999))
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not match session"), "{err}");
        // parent on an unknown session is rejected
        let err =
            client.generate_in_session("x", 2, "lychee", "nope", Some(1)).unwrap_err().to_string();
        assert!(err.contains("no prior turn"), "{err}");
        // correct chaining: turn 2's engine prompt = turn1 + reply + turn2
        let turn2 = String::from_utf8(crate::workloads::trace::prompt_text(150, 22)).unwrap();
        let r2 = client
            .generate_in_session(&turn2, 4, "lychee", "s1", Some(r1.request_id))
            .unwrap();
        assert_eq!(r2.tokens, 4);

        std::thread::sleep(std::time::Duration::from_millis(100));
        let m = client.metrics().unwrap();
        // turn 2's 554-token prompt shares turn 1's sealed 384-token
        // prefix -> at least one radix hit with >= 6 pages reused
        assert!(m.get("prefix_hits").as_usize().unwrap_or(0) >= 1, "no radix hit: {m:?}");
        assert!(m.get("prefix_tokens_reused").as_usize().unwrap_or(0) >= 384);
        assert!(m.get("kv_bytes_shared").as_f64().is_some());
        assert!(m.get("prefix_evictions").as_f64().is_some());
        assert!(m.get("selects_before_build").as_f64().is_some());
        assert!(m.get("blocks_scanned_total").as_f64().is_some());
        assert!(m.get("blocks_pruned_total").as_f64().is_some());
        server.stop();
        handle.shutdown();
        join.join().unwrap();
    }

    fn parse(s: &str) -> std::result::Result<WireRequest, String> {
        parse_request(&Json::parse(s).unwrap())
    }

    #[test]
    fn session_store_is_lru_bounded() {
        let cap = 16;
        let mut s = SessionStore::new(cap);
        for i in 0..(cap + 10) {
            s.update(&format!("s{i}"), i as u64, vec![b'x']);
        }
        assert_eq!(s.map.len(), cap, "store not bounded");
        assert!(s.touch("s0").is_none(), "oldest session survived");
        assert!(s.touch(&format!("s{}", cap + 9)).is_some(), "newest session lost");
        // a zero cap is clamped to 1 rather than panicking
        let mut s = SessionStore::new(0);
        s.update("a", 1, vec![b'a']);
        s.update("b", 2, vec![b'b']);
        assert_eq!(s.map.len(), 1);
        assert!(s.touch("b").is_some());
    }

    #[test]
    fn parse_request_session_fields() {
        let w = parse(r#"{"prompt": "hi", "session_id": "s9"}"#).unwrap();
        assert_eq!(w.session_id.as_deref(), Some("s9"));
        assert_eq!(w.parent, None);
        let w = parse(r#"{"prompt": "hi", "session_id": "s9", "parent": 12}"#).unwrap();
        assert_eq!(w.parent, Some(12));
        // anonymous requests parse with no session
        let w = parse(r#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(w.session_id, None);
        // malformed session fields get structured errors
        assert!(parse(r#"{"prompt": "x", "session_id": 3}"#).unwrap_err().contains("string"));
        assert!(parse(r#"{"prompt": "x", "session_id": ""}"#).unwrap_err().contains("non-empty"));
        assert!(parse(r#"{"prompt": "x", "parent": 1}"#)
            .unwrap_err()
            .contains("requires 'session_id'"));
        let e = parse(r#"{"prompt": "x", "session_id": "s", "parent": -2}"#).unwrap_err();
        assert!(e.contains("request id"), "{e}");
        let e = parse(r#"{"prompt": "x", "session_id": "s", "parent": 1.5}"#).unwrap_err();
        assert!(e.contains("request id"), "{e}");
    }

    #[test]
    fn parse_request_accepts_valid_and_defaults() {
        let w = parse(r#"{"prompt": "hi", "max_new_tokens": 8, "policy": "full"}"#).unwrap();
        assert_eq!(w.prompt, b"hi".to_vec());
        assert_eq!(w.max_new_tokens, Some(8));
        assert_eq!(w.policy, "full");
        let w = parse(r#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(w.max_new_tokens, None);
        assert_eq!(w.policy, "lychee");
    }

    #[test]
    fn parse_request_rejects_zero_and_junk_token_counts() {
        assert!(parse(r#"{"max_new_tokens": 4}"#).unwrap_err().contains("prompt"));
        let e = parse(r#"{"prompt": "x", "max_new_tokens": 0}"#).unwrap_err();
        assert!(e.contains(">= 1"), "{e}");
        let e = parse(r#"{"prompt": "x", "max_new_tokens": 2.5}"#).unwrap_err();
        assert!(e.contains("integer"), "{e}");
        let e = parse(r#"{"prompt": "x", "max_new_tokens": -3}"#).unwrap_err();
        assert!(e.contains("integer"), "{e}");
        let e = parse(r#"{"prompt": "x", "max_new_tokens": "many"}"#).unwrap_err();
        assert!(e.contains("integer"), "{e}");
        // huge values are accepted here; the coordinator clamps them
        let w = parse(r#"{"prompt": "x", "max_new_tokens": 1000000}"#).unwrap();
        assert_eq!(w.max_new_tokens, Some(1_000_000));
    }

    #[test]
    fn parse_request_validates_deadline() {
        let w = parse(r#"{"prompt": "hi", "deadline_ms": 250}"#).unwrap();
        assert_eq!(w.deadline_ms, Some(250));
        // 0 is valid: it explicitly disables the configured default
        let w = parse(r#"{"prompt": "hi", "deadline_ms": 0}"#).unwrap();
        assert_eq!(w.deadline_ms, Some(0));
        let w = parse(r#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(w.deadline_ms, None);
        let e = parse(r#"{"prompt": "x", "deadline_ms": -5}"#).unwrap_err();
        assert!(e.contains("non-negative integer"), "{e}");
        let e = parse(r#"{"prompt": "x", "deadline_ms": 1.5}"#).unwrap_err();
        assert!(e.contains("non-negative integer"), "{e}");
        let e = parse(r#"{"prompt": "x", "deadline_ms": "soon"}"#).unwrap_err();
        assert!(e.contains("non-negative integer"), "{e}");
    }

    /// Cancellation, deadlines, and drain over the wire: each lifecycle
    /// terminal gets a structured line, and the scrape accounts for all
    /// of them. Wall-clock-dependent (which chunk a cancel lands on),
    /// so assertions are on outcomes and counters, not transcripts.
    #[test]
    fn sim_lifecycle_cancel_deadline_drain_over_the_wire() {
        let mut cfg = crate::config::Config::new();
        cfg.serving.prefill_chunk_tokens = 32;
        cfg.serving.max_batch = 2;
        let engine_cfg = cfg.clone();
        let (handle, metrics, join) = crate::coordinator::spawn_with(cfg, move || {
            Ok(crate::engine::sim::SimEngine::new(
                engine_cfg,
                crate::engine::sim::SimConfig::default(),
            ))
        })
        .unwrap();
        let server = Server::start("127.0.0.1:0", handle.clone(), Some(metrics)).unwrap();
        let addr = server.addr;
        let mut admin = Client::connect(&addr).unwrap();

        let scrape = |c: &mut Client, key: &str| -> usize {
            c.metrics().unwrap().get(key).as_usize().unwrap_or(0)
        };
        let long_prompt =
            String::from_utf8(crate::workloads::trace::prompt_text(1500, 91)).unwrap();

        // ---- cancel: request 1 starts prefilling, admin cancels it ----
        let p1 = long_prompt.clone();
        let t1 = std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            c.generate(&p1, 48, "lychee").unwrap_err().to_string()
        });
        // wait until it is actually executing (1500 tokens / 32-token
        // chunks: many ticks of runway before it could finish)
        while scrape(&mut admin, "prefill_chunks_executed") < 1 {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        admin.cancel(1).unwrap();
        let err = t1.join().unwrap();
        assert!(err.contains("request 1: cancelled"), "{err}");

        // ---- deadline: 1ms budget on a 1500-token prompt ----
        let err = admin
            .generate_with_deadline(&long_prompt, 48, "lychee", 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("deadline_exceeded"), "{err}");

        // ---- drain: request 3 is in flight, then admission closes ----
        let chunks_before = scrape(&mut admin, "prefill_chunks_executed");
        let p3 = long_prompt.clone();
        let t3 = std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            c.generate(&p3, 8, "lychee").map(|r| r.tokens)
        });
        while scrape(&mut admin, "prefill_chunks_executed") <= chunks_before {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        admin.drain().unwrap();
        // same connection as the drain, so the coordinator sees Drain
        // before this Submit: structured reject, not a hang
        let err = admin.generate("too late", 4, "lychee").unwrap_err().to_string();
        assert!(err.contains("draining"), "{err}");
        // in-flight work still finishes under drain
        assert_eq!(t3.join().unwrap().unwrap(), 8);
        // the scheduler thread exits once drained
        join.join().unwrap();

        let m = admin.metrics().unwrap();
        assert_eq!(m.get("cancellations").as_usize(), Some(1), "{m:?}");
        assert_eq!(m.get("deadline_exceeded").as_usize(), Some(1), "{m:?}");
        assert_eq!(m.get("drain_state").as_usize(), Some(2), "{m:?}");
        assert_eq!(m.get("requests_in_flight").as_usize(), Some(0), "{m:?}");
        // private pages are all returned; only radix-sealed shared pages
        // (request 3's prefix) may remain resident in the pool gauge
        let in_use = m.get("kv_bytes_in_use").as_usize().unwrap_or(usize::MAX);
        let shared = m.get("kv_bytes_shared").as_usize().unwrap_or(0);
        assert_eq!(in_use, shared, "{m:?}");
        server.stop();
    }

    /// Satellite pin: the legacy threads front must notice a mid-stream
    /// client disconnect via read-EOF polling (not only via failed
    /// writes, which TCP buffering defers for a window's worth of
    /// tokens), cancel coordinator-side, and return every private KV
    /// page to the pool.
    #[test]
    fn threads_frontend_disconnect_cancels_and_frees_pages() {
        let mut cfg = crate::config::Config::new();
        cfg.serving.prefill_chunk_tokens = 32;
        let engine_cfg = cfg.clone();
        let (handle, metrics, join) = crate::coordinator::spawn_with(cfg, move || {
            Ok(crate::engine::sim::SimEngine::new(
                engine_cfg,
                crate::engine::sim::SimConfig {
                    // slow decode: the stream is alive long enough for
                    // the disconnect to land mid-generation
                    decode_us_per_step: 2000,
                    ..crate::engine::sim::SimConfig::default()
                },
            ))
        })
        .unwrap();
        let server =
            Server::start_single("127.0.0.1:0", handle.clone(), Some(metrics.clone()), 64)
                .unwrap();

        // start a long stream, read a few bytes, vanish
        {
            use std::io::Read;
            let mut stream = TcpStream::connect(server.addr).unwrap();
            writeln!(stream, r#"{{"prompt": "disconnect me", "max_new_tokens": 500}}"#).unwrap();
            let mut first = [0u8; 8];
            stream.read_exact(&mut first).unwrap();
        } // dropped: the server sees read-EOF between token writes

        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let (cancels, in_use, shared) = {
                let m = lock_recover(&metrics);
                (m.cancellations, m.kv_bytes_in_use, m.kv_bytes_shared)
            };
            if cancels == 1 && in_use == shared {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "disconnect never cancelled: cancels={cancels} in_use={in_use} shared={shared}"
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        server.stop();
        handle.shutdown();
        join.join().unwrap();
    }

    #[test]
    fn parse_request_validates_policy_names() {
        let e = parse(r#"{"prompt": "x", "policy": "nope"}"#).unwrap_err();
        assert!(e.contains("unknown policy 'nope'"), "{e}");
        assert!(e.contains("lychee"), "should list valid policies: {e}");
        let e = parse(r#"{"prompt": "x", "policy": 3}"#).unwrap_err();
        assert!(e.contains("string"), "{e}");
        for name in crate::sparse::POLICY_NAMES {
            let w = parse(&format!(r#"{{"prompt": "x", "policy": "{name}"}}"#)).unwrap();
            assert_eq!(w.policy, *name);
        }
    }

    #[test]
    fn bad_request_gets_error_line() {
        let Some(cfg) = test_config() else { return };
        let (handle, _m, join) = spawn(cfg).unwrap();
        let server = Server::start("127.0.0.1:0", handle.clone(), None).unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        writeln!(stream, "{{\"nope\": 1}}").unwrap();
        let mut line = String::new();
        BufReader::new(stream.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("error"), "got: {line}");
        server.stop();
        handle.shutdown();
        join.join().unwrap();
    }

    fn sim_server(
        cfg: crate::config::Config,
    ) -> (Handle, Arc<Mutex<Metrics>>, std::thread::JoinHandle<()>, Server) {
        let cap = cfg.serving.session_store_cap;
        let engine_cfg = cfg.clone();
        let (handle, metrics, join) = crate::coordinator::spawn_with(cfg, move || {
            Ok(crate::engine::sim::SimEngine::new(
                engine_cfg,
                crate::engine::sim::SimConfig::default(),
            ))
        })
        .unwrap();
        let server =
            Server::start_single("127.0.0.1:0", handle.clone(), Some(metrics.clone()), cap)
                .unwrap();
        (handle, metrics, join, server)
    }

    /// Sends one raw request line and parses the single reply line (the
    /// structured-error path never streams, so one line is the whole
    /// exchange).
    fn raw_reply(addr: &std::net::SocketAddr, line: &str) -> Json {
        let mut stream = TcpStream::connect(addr).unwrap();
        writeln!(stream, "{line}").unwrap();
        let mut reply = String::new();
        BufReader::new(stream.try_clone().unwrap()).read_line(&mut reply).unwrap();
        Json::parse(&reply).unwrap()
    }

    /// Session protocol errors carry a machine-readable `code` so
    /// clients can tell the retryable condition (session evicted or
    /// never seen: replay history as a fresh turn) from the protocol
    /// bug (stale `parent`: refetch the head first).
    #[test]
    fn session_errors_carry_machine_readable_codes() {
        let (handle, _m, join, server) = sim_server(crate::config::Config::new());
        let mut client = Client::connect(&server.addr).unwrap();
        let r1 = client.generate_in_session("turn one", 3, "lychee", "s1", None).unwrap();

        let j = raw_reply(
            &server.addr,
            &format!(
                r#"{{"prompt": "x", "session_id": "s1", "parent": {}}}"#,
                r1.request_id + 999
            ),
        );
        assert_eq!(j.get("code").as_str(), Some("parent_mismatch"), "{j:?}");
        assert!(j.get("error").as_str().unwrap_or("").contains("does not match session"));

        let j = raw_reply(&server.addr, r#"{"prompt": "x", "session_id": "never", "parent": 7}"#);
        assert_eq!(j.get("code").as_str(), Some("session_unknown"), "{j:?}");
        assert!(j.get("error").as_str().unwrap_or("").contains("no prior turn"));

        server.stop();
        handle.shutdown();
        join.join().unwrap();
    }

    /// `serving.session_store_cap` bounds the per-server session store:
    /// with cap 2, the third session evicts the first, and a follow-up
    /// turn against the evicted session reports `session_unknown`.
    #[test]
    fn session_store_cap_knob_is_honored() {
        let mut cfg = crate::config::Config::new();
        cfg.serving.session_store_cap = 2;
        let (handle, _m, join, server) = sim_server(cfg);
        let mut client = Client::connect(&server.addr).unwrap();

        let r1 = client.generate_in_session("one", 2, "lychee", "a", None).unwrap();
        let _r2 = client.generate_in_session("two", 2, "lychee", "b", None).unwrap();
        let r3 = client.generate_in_session("three", 2, "lychee", "c", None).unwrap();

        // session "a" was evicted by "c": its parent is now unknown
        let j = raw_reply(
            &server.addr,
            &format!(r#"{{"prompt": "x", "session_id": "a", "parent": {}}}"#, r1.request_id),
        );
        assert_eq!(j.get("code").as_str(), Some("session_unknown"), "{j:?}");
        // the two newest sessions still chain
        let r4 = client
            .generate_in_session("more", 2, "lychee", "c", Some(r3.request_id))
            .unwrap();
        assert_eq!(r4.tokens, 2);

        server.stop();
        handle.shutdown();
        join.join().unwrap();
    }

    /// Full wire round-trip through the sharded tier: a 2-shard cluster
    /// behind `Server::start_cluster` serves generation, sessions, and
    /// drain exactly like the single-coordinator server.
    #[test]
    fn cluster_round_trip_over_tcp() {
        let mut cfg = crate::config::Config::new();
        cfg.serving.shards = 2;
        cfg.serving.prefill_chunk_tokens = 64;
        let cluster = crate::coordinator::cluster::spawn_cluster_with(cfg, |_, engine_cfg| {
            Ok(crate::engine::sim::SimEngine::new(
                engine_cfg,
                crate::engine::sim::SimConfig::default(),
            ))
        })
        .unwrap();
        let server = Server::start_cluster("127.0.0.1:0", cluster.clone(), 64).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();

        // spread a handful of distinct prompts across the ring
        for i in 0..6 {
            let prompt =
                String::from_utf8(crate::workloads::trace::prompt_text(200, 40 + i)).unwrap();
            let res = client.generate(&prompt, 4, "lychee").unwrap();
            assert_eq!(res.tokens, 4, "request {i}");
            assert!(!res.text.is_empty());
        }
        // session chaining rides the same content-hash routing (the
        // server prepends history, so turns share a prefix -> a shard)
        let r1 = client.generate_in_session("cluster turn", 3, "lychee", "cs", None).unwrap();
        let r2 = client
            .generate_in_session("next", 3, "lychee", "cs", Some(r1.request_id))
            .unwrap();
        assert_eq!(r2.tokens, 3);

        // drain quiesces every shard; late submits are rejected
        let mut admin = Client::connect(&server.addr).unwrap();
        admin.drain().unwrap();
        let err = admin.generate("too late", 2, "lychee").unwrap_err().to_string();
        assert!(err.contains("draining"), "{err}");

        server.stop();
        cluster.join();
    }

    /// Cluster scrape shape: aggregate gauges keep the flat single-node
    /// keys at the top level, and the reply adds a `"shards"` array
    /// (health + full per-shard gauges) and a `"router"` object.
    #[test]
    fn cluster_scrape_reports_shards_and_aggregate() {
        let mut cfg = crate::config::Config::new();
        cfg.serving.shards = 2;
        let cluster = crate::coordinator::cluster::spawn_cluster_with(cfg, |_, engine_cfg| {
            Ok(crate::engine::sim::SimEngine::new(
                engine_cfg,
                crate::engine::sim::SimConfig::default(),
            ))
        })
        .unwrap();
        let server = Server::start_cluster("127.0.0.1:0", cluster.clone(), 64).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();

        let total: usize = (0..4)
            .map(|i| {
                let prompt =
                    String::from_utf8(crate::workloads::trace::prompt_text(150, 70 + i)).unwrap();
                client.generate(&prompt, 3, "lychee").unwrap().tokens
            })
            .sum();
        assert_eq!(total, 12);

        // one idle tick so queue gauges settle
        std::thread::sleep(std::time::Duration::from_millis(100));
        let m = client.metrics().unwrap();
        // aggregate keeps the flat keys dashboards already scrape
        assert_eq!(m.get("completed").as_usize(), Some(4), "{m:?}");
        assert_eq!(m.get("tokens_out").as_usize(), Some(12), "{m:?}");
        assert_eq!(m.get("requests_in_flight").as_usize(), Some(0));
        assert_eq!(m.get("sheds").as_usize(), Some(0));
        assert!(m.get("ttft_p50_us").as_f64().is_some());
        // per-shard breakdown with health
        let shards = m.get("shards").as_arr().expect("shards array");
        assert_eq!(shards.len(), 2);
        let mut per_shard_completed = 0;
        for (i, s) in shards.iter().enumerate() {
            assert_eq!(s.get("shard").as_usize(), Some(i));
            assert_eq!(s.get("alive").as_bool(), Some(true));
            assert!(s.get("heartbeat_ticks").as_f64().unwrap_or(0.0) > 0.0);
            per_shard_completed += s.get("completed").as_usize().unwrap_or(0);
        }
        assert_eq!(per_shard_completed, 4, "per-shard gauges must sum to the aggregate");
        // router counters
        let router = m.get("router");
        assert_eq!(router.get("routed_total").as_usize(), Some(4), "{m:?}");
        assert_eq!(router.get("failovers_total").as_usize(), Some(0));

        server.stop();
        cluster.shutdown();
        cluster.join();
    }
}
