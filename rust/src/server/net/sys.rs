//! Raw OS bindings for the event-driven serving front: `epoll` +
//! `eventfd` on Linux, portable `poll(2)` + self-pipe everywhere else.
//! Declared directly against libc (which std already links) — no new
//! crates, per the repo's vendored-offline policy. Everything is wrapped
//! in safe `io::Result` functions with `EINTR` handled; callers never
//! touch the externs.

use std::io;
use std::os::raw::{c_int, c_uint, c_void};
use std::os::unix::io::RawFd;

#[cfg(target_os = "linux")]
type NfdsT = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type NfdsT = c_uint;

// ------------------------------------------------------------ constants

pub const POLLIN: i16 = 0x001;
pub const POLLOUT: i16 = 0x004;
pub const POLLERR: i16 = 0x008;
pub const POLLHUP: i16 = 0x010;
pub const POLLNVAL: i16 = 0x020;

const F_GETFD: c_int = 1;
const F_SETFD: c_int = 2;
const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;
const FD_CLOEXEC: c_int = 1;

#[cfg(target_os = "linux")]
const O_NONBLOCK: c_int = 0x800;
#[cfg(not(target_os = "linux"))]
const O_NONBLOCK: c_int = 0x4;

#[cfg(target_os = "linux")]
pub const EPOLLIN: u32 = 0x001;
#[cfg(target_os = "linux")]
pub const EPOLLOUT: u32 = 0x004;
#[cfg(target_os = "linux")]
pub const EPOLLERR: u32 = 0x008;
#[cfg(target_os = "linux")]
pub const EPOLLHUP: u32 = 0x010;
#[cfg(target_os = "linux")]
pub const EPOLL_CTL_ADD: c_int = 1;
#[cfg(target_os = "linux")]
pub const EPOLL_CTL_DEL: c_int = 2;
#[cfg(target_os = "linux")]
pub const EPOLL_CTL_MOD: c_int = 3;
#[cfg(target_os = "linux")]
const EPOLL_CLOEXEC: c_int = 0x80000;
#[cfg(target_os = "linux")]
const EFD_CLOEXEC: c_int = 0x80000;
#[cfg(target_os = "linux")]
const EFD_NONBLOCK: c_int = 0x800;

#[cfg(target_os = "linux")]
const RLIMIT_NOFILE: c_int = 7;
#[cfg(not(target_os = "linux"))]
const RLIMIT_NOFILE: c_int = 8;

// -------------------------------------------------------------- structs

/// `struct pollfd`, identical layout on every unix.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    pub fd: c_int,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn interest(fd: RawFd, readable: bool, writable: bool) -> PollFd {
        let mut events = 0i16;
        if readable {
            events |= POLLIN;
        }
        if writable {
            events |= POLLOUT;
        }
        PollFd { fd, events, revents: 0 }
    }
}

/// `struct epoll_event`: packed on x86_64 (the kernel ABI), natural
/// alignment elsewhere.
#[cfg(target_os = "linux")]
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    /// Caller-chosen token (we never store pointers here).
    pub data: u64,
}

/// `struct rlimit` (both fields `rlim_t` = u64 on 64-bit unix).
#[repr(C)]
#[derive(Clone, Copy)]
struct RLimit {
    cur: u64,
    max: u64,
}

// -------------------------------------------------------------- externs

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    fn pipe(fds: *mut c_int) -> c_int;
    fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
}

#[cfg(target_os = "linux")]
extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn eventfd(initval: c_uint, flags: c_int) -> c_int;
}

// ------------------------------------------------------------- wrappers

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// `poll(2)` over the whole slice. `EINTR` reports as zero ready fds —
/// callers run a level-triggered loop, so a spurious empty wake is safe.
pub fn sys_poll(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    // SAFETY: `fds` is a valid, exclusively-borrowed slice of repr(C)
    // pollfd records; the kernel writes only `revents` within bounds.
    let ret = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
    match cvt(ret) {
        Ok(n) => Ok(n as usize),
        Err(e) if e.kind() == io::ErrorKind::Interrupted => Ok(0),
        Err(e) => Err(e),
    }
}

/// A nonblocking close-on-exec pipe: `(read_end, write_end)`.
pub fn sys_pipe_nonblocking() -> io::Result<(RawFd, RawFd)> {
    let mut fds = [0 as c_int; 2];
    // SAFETY: `fds` is a valid 2-element array the kernel fills.
    cvt(unsafe { pipe(fds.as_mut_ptr()) })?;
    for fd in fds {
        if let Err(e) = set_nonblocking(fd).and_then(|_| set_cloexec(fd)) {
            sys_close(fds[0]);
            sys_close(fds[1]);
            return Err(e);
        }
    }
    Ok((fds[0], fds[1]))
}

/// Put an fd into nonblocking mode (used on raw fds; sockets go through
/// `TcpStream::set_nonblocking`).
pub fn set_nonblocking(fd: RawFd) -> io::Result<()> {
    // SAFETY: plain fcntl on a caller-owned fd; no pointers involved.
    let flags = cvt(unsafe { fcntl(fd, F_GETFL) })?;
    // SAFETY: as above; the third variadic argument is the int flag set.
    cvt(unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) })?;
    Ok(())
}

fn set_cloexec(fd: RawFd) -> io::Result<()> {
    // SAFETY: plain fcntl on a caller-owned fd; no pointers involved.
    let flags = cvt(unsafe { fcntl(fd, F_GETFD) })?;
    // SAFETY: as above; the third variadic argument is the int flag set.
    cvt(unsafe { fcntl(fd, F_SETFD, flags | FD_CLOEXEC) })?;
    Ok(())
}

/// Nonblocking read on a raw fd (waker pipes / eventfds only).
pub fn sys_read(fd: RawFd, buf: &mut [u8]) -> io::Result<usize> {
    // SAFETY: `buf` is a valid exclusively-borrowed byte buffer; the
    // kernel writes at most `buf.len()` bytes into it.
    let n = unsafe { read(fd, buf.as_mut_ptr() as *mut c_void, buf.len()) };
    if n < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(n as usize)
    }
}

/// Nonblocking write on a raw fd (waker pipes / eventfds only).
pub fn sys_write(fd: RawFd, buf: &[u8]) -> io::Result<usize> {
    // SAFETY: `buf` is a valid borrowed byte buffer; the kernel reads at
    // most `buf.len()` bytes from it.
    let n = unsafe { write(fd, buf.as_ptr() as *const c_void, buf.len()) };
    if n < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(n as usize)
    }
}

/// Close a raw fd owned by this module (best-effort; double-close is the
/// caller's bug and is prevented by ownership in `Waker`/`Poller`).
pub fn sys_close(fd: RawFd) {
    // SAFETY: the fd is owned by the caller and not used again after.
    let _ = unsafe { close(fd) };
}

/// Current `RLIMIT_NOFILE` as `(soft, hard)`.
pub fn nofile_limit() -> io::Result<(u64, u64)> {
    let mut lim = RLimit { cur: 0, max: 0 };
    // SAFETY: `lim` is a valid repr(C) rlimit the kernel fills.
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) })?;
    Ok((lim.cur, lim.max))
}

/// Raise the soft fd limit toward `want` (clamped at the hard limit),
/// returning the resulting soft limit. High-concurrency benches need
/// ~2 fds per in-flight stream; the default soft limit of 1024 caps out
/// under 512 streams.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    let (soft, hard) = nofile_limit()?;
    let target = want.min(hard);
    if target <= soft {
        return Ok(soft);
    }
    let lim = RLimit { cur: target, max: hard };
    // SAFETY: `lim` is a valid repr(C) rlimit read by the kernel.
    cvt(unsafe { setrlimit(RLIMIT_NOFILE, &lim) })?;
    Ok(target)
}

// ------------------------------------------------------- linux-only: epoll

/// New close-on-exec epoll instance.
#[cfg(target_os = "linux")]
pub fn sys_epoll_create() -> io::Result<RawFd> {
    // SAFETY: no pointers; returns a new fd or -1.
    cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })
}

/// Add/modify/delete `fd` in the interest list with a caller token.
#[cfg(target_os = "linux")]
pub fn sys_epoll_ctl(epfd: RawFd, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
    let mut ev = EpollEvent { events, data: token };
    // SAFETY: `ev` is a valid repr(C) epoll_event; for EPOLL_CTL_DEL the
    // kernel ignores the pointer but a valid one is passed anyway
    // (pre-2.6.9 kernels required it).
    cvt(unsafe { epoll_ctl(epfd, op, fd, &mut ev) })?;
    Ok(())
}

/// Wait for events; `EINTR` reports as zero events (see [`sys_poll`]).
#[cfg(target_os = "linux")]
pub fn sys_epoll_wait(
    epfd: RawFd,
    events: &mut [EpollEvent],
    timeout_ms: i32,
) -> io::Result<usize> {
    // SAFETY: `events` is a valid exclusively-borrowed slice; the kernel
    // writes at most `events.len()` records.
    let ret =
        unsafe { epoll_wait(epfd, events.as_mut_ptr(), events.len() as c_int, timeout_ms) };
    match cvt(ret) {
        Ok(n) => Ok(n as usize),
        Err(e) if e.kind() == io::ErrorKind::Interrupted => Ok(0),
        Err(e) => Err(e),
    }
}

/// Nonblocking close-on-exec eventfd (the reactor's wakeup channel).
#[cfg(target_os = "linux")]
pub fn sys_eventfd() -> io::Result<RawFd> {
    // SAFETY: no pointers; returns a new fd or -1.
    cvt(unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipe_round_trips_a_byte_nonblocking() {
        let (r, w) = sys_pipe_nonblocking().unwrap();
        // empty pipe: nonblocking read must not block
        let mut buf = [0u8; 8];
        let e = sys_read(r, &mut buf).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::WouldBlock);
        assert_eq!(sys_write(w, b"x").unwrap(), 1);
        assert_eq!(sys_read(r, &mut buf).unwrap(), 1);
        assert_eq!(buf[0], b'x');
        sys_close(r);
        sys_close(w);
    }

    #[test]
    fn poll_reports_readability() {
        let (r, w) = sys_pipe_nonblocking().unwrap();
        let mut fds = [PollFd::interest(r, true, false)];
        // nothing readable yet: times out with zero ready
        assert_eq!(sys_poll(&mut fds, 0).unwrap(), 0);
        sys_write(w, b"!").unwrap();
        fds[0].revents = 0;
        assert_eq!(sys_poll(&mut fds, 1000).unwrap(), 1);
        assert_ne!(fds[0].revents & POLLIN, 0);
        sys_close(r);
        sys_close(w);
    }

    #[test]
    fn nofile_limit_reads_and_raises() {
        let (soft, hard) = nofile_limit().unwrap();
        assert!(soft > 0 && hard >= soft);
        // raising to the current soft limit is a no-op success
        assert_eq!(raise_nofile_limit(soft).unwrap(), soft);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn eventfd_and_epoll_round_trip() {
        let efd = sys_eventfd().unwrap();
        let ep = sys_epoll_create().unwrap();
        sys_epoll_ctl(ep, EPOLL_CTL_ADD, efd, EPOLLIN, 42).unwrap();
        let mut evs = [EpollEvent { events: 0, data: 0 }; 4];
        assert_eq!(sys_epoll_wait(ep, &mut evs, 0).unwrap(), 0);
        // signal the eventfd: epoll must report token 42 readable
        sys_write(efd, &1u64.to_ne_bytes()).unwrap();
        assert_eq!(sys_epoll_wait(ep, &mut evs, 1000).unwrap(), 1);
        let (events, data) = (evs[0].events, evs[0].data);
        assert_ne!(events & EPOLLIN, 0);
        assert_eq!(data, 42);
        // drain resets it
        let mut buf = [0u8; 8];
        assert_eq!(sys_read(efd, &mut buf).unwrap(), 8);
        assert_eq!(u64::from_ne_bytes(buf), 1);
        sys_epoll_ctl(ep, EPOLL_CTL_DEL, efd, 0, 0).unwrap();
        sys_close(ep);
        sys_close(efd);
    }
}
