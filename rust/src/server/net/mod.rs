//! Event-driven I/O plumbing for the reactor serving front
//! (`serving.frontend = epoll`): a [`Poller`] multiplexing readiness
//! over every client socket from one thread, and a [`Waker`] that lets
//! coordinator/cluster threads interrupt the poll wait when a token
//! event lands. Linux uses `epoll` + `eventfd`; every other unix runs
//! the same API over portable `poll(2)` + a self-pipe. Both backends
//! compile on Linux so the fallback is exercised by tests, not just by
//! other platforms.
//!
//! Level-triggered on both backends: a fd with unread input (or writable
//! space and queued output) reports ready on every wait, so the reactor
//! loop never needs edge-triggered bookkeeping.

pub mod sys;

pub mod reactor;

use std::collections::HashMap;
use std::io;
use std::os::unix::io::RawFd;
use std::sync::atomic::{AtomicBool, Ordering};

/// One readiness report from [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    /// The token the fd was registered under.
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Peer hung up (`EPOLLHUP`/`POLLHUP`); a subsequent read returns 0.
    pub hangup: bool,
    /// Error condition on the fd (`EPOLLERR`/`POLLERR`/`POLLNVAL`).
    pub error: bool,
}

/// Readiness multiplexer over raw fds, epoll- or poll-backed.
pub struct Poller {
    backend: Backend,
}

enum Backend {
    #[cfg(target_os = "linux")]
    Epoll(EpollBackend),
    Poll(PollBackend),
}

impl Poller {
    /// Platform-preferred backend: epoll on Linux, poll elsewhere.
    pub fn new() -> io::Result<Poller> {
        #[cfg(target_os = "linux")]
        {
            Ok(Poller { backend: Backend::Epoll(EpollBackend::new()?) })
        }
        #[cfg(not(target_os = "linux"))]
        {
            Self::new_poll()
        }
    }

    /// Force the portable `poll(2)` backend (tests; non-Linux default).
    pub fn new_poll() -> io::Result<Poller> {
        Ok(Poller { backend: Backend::Poll(PollBackend::new()) })
    }

    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(_) => "epoll",
            Backend::Poll(_) => "poll",
        }
    }

    /// Start watching `fd` under `token` for the given readiness kinds.
    pub fn register(
        &mut self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(b) => b.ctl(sys::EPOLL_CTL_ADD, fd, token, readable, writable),
            Backend::Poll(b) => {
                b.interest.insert(fd, (token, readable, writable));
                Ok(())
            }
        }
    }

    /// Change the readiness kinds watched for an already-registered fd.
    pub fn modify(
        &mut self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(b) => b.ctl(sys::EPOLL_CTL_MOD, fd, token, readable, writable),
            Backend::Poll(b) => {
                b.interest.insert(fd, (token, readable, writable));
                Ok(())
            }
        }
    }

    /// Stop watching `fd` (must precede closing it).
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(b) => b.ctl(sys::EPOLL_CTL_DEL, fd, 0, false, false),
            Backend::Poll(b) => {
                b.interest.remove(&fd);
                Ok(())
            }
        }
    }

    /// Block up to `timeout_ms` for readiness; `out` is cleared and
    /// refilled. A zero-event return (timeout or `EINTR`) is normal.
    pub fn wait(&mut self, out: &mut Vec<PollEvent>, timeout_ms: i32) -> io::Result<()> {
        out.clear();
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(b) => b.wait(out, timeout_ms),
            Backend::Poll(b) => b.wait(out, timeout_ms),
        }
    }
}

// ------------------------------------------------------- epoll backend

#[cfg(target_os = "linux")]
struct EpollBackend {
    epfd: RawFd,
    buf: Vec<sys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollBackend {
    fn new() -> io::Result<EpollBackend> {
        Ok(EpollBackend {
            epfd: sys::sys_epoll_create()?,
            buf: vec![sys::EpollEvent { events: 0, data: 0 }; 1024],
        })
    }

    fn ctl(
        &mut self,
        op: std::os::raw::c_int,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        let mut events = 0u32;
        if readable {
            events |= sys::EPOLLIN;
        }
        if writable {
            events |= sys::EPOLLOUT;
        }
        sys::sys_epoll_ctl(self.epfd, op, fd, events, token)
    }

    fn wait(&mut self, out: &mut Vec<PollEvent>, timeout_ms: i32) -> io::Result<()> {
        let n = sys::sys_epoll_wait(self.epfd, &mut self.buf, timeout_ms)?;
        for ev in &self.buf[..n] {
            // copy out of the (possibly packed) record before testing bits
            let (events, token) = (ev.events, ev.data);
            out.push(PollEvent {
                token,
                readable: events & sys::EPOLLIN != 0,
                writable: events & sys::EPOLLOUT != 0,
                hangup: events & sys::EPOLLHUP != 0,
                error: events & sys::EPOLLERR != 0,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollBackend {
    fn drop(&mut self) {
        sys::sys_close(self.epfd);
    }
}

// -------------------------------------------------------- poll backend

/// Portable fallback: the interest set is rebuilt into a `pollfd` array
/// on every wait. O(fds) per wait versus epoll's O(ready), which is fine
/// for the fallback's role (non-Linux platforms and backend-parity
/// tests); Linux production serving takes the epoll arm.
struct PollBackend {
    interest: HashMap<RawFd, (u64, bool, bool)>,
    fds: Vec<sys::PollFd>,
}

impl PollBackend {
    fn new() -> PollBackend {
        PollBackend { interest: HashMap::new(), fds: Vec::new() }
    }

    fn wait(&mut self, out: &mut Vec<PollEvent>, timeout_ms: i32) -> io::Result<()> {
        self.fds.clear();
        let mut tokens = Vec::with_capacity(self.interest.len());
        for (&fd, &(token, r, w)) in &self.interest {
            self.fds.push(sys::PollFd::interest(fd, r, w));
            tokens.push(token);
        }
        let n = sys::sys_poll(&mut self.fds, timeout_ms)?;
        if n == 0 {
            return Ok(());
        }
        for (pfd, &token) in self.fds.iter().zip(&tokens) {
            let re = pfd.revents;
            if re == 0 {
                continue;
            }
            out.push(PollEvent {
                token,
                readable: re & sys::POLLIN != 0,
                writable: re & sys::POLLOUT != 0,
                hangup: re & sys::POLLHUP != 0,
                error: re & (sys::POLLERR | sys::POLLNVAL) != 0,
            });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- waker

/// Cross-thread wakeup for a [`Poller`] wait: scheduler/relay threads
/// call [`Waker::wake`] after pushing an event, the reactor registers
/// [`Waker::read_fd`] and calls [`Waker::drain`] when it reports
/// readable. Eventfd on Linux, self-pipe elsewhere. Wakes coalesce
/// (both carriers saturate rather than queue), which is exactly the
/// semantics a level-triggered drain loop wants.
pub struct Waker {
    read_fd: RawFd,
    /// Same fd as `read_fd` for eventfd, the pipe's write end otherwise.
    write_fd: RawFd,
    /// Pipe carrier: skip redundant writes while a wake is pending
    /// (an eventfd coalesces natively; a pipe would fill).
    pending: AtomicBool,
    /// Whether dropping should close `write_fd` separately.
    two_fds: bool,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        #[cfg(target_os = "linux")]
        {
            let efd = sys::sys_eventfd()?;
            Ok(Waker {
                read_fd: efd,
                write_fd: efd,
                pending: AtomicBool::new(false),
                two_fds: false,
            })
        }
        #[cfg(not(target_os = "linux"))]
        {
            Self::new_pipe()
        }
    }

    /// Force the self-pipe carrier (tests; non-Linux default).
    pub fn new_pipe() -> io::Result<Waker> {
        let (r, w) = sys::sys_pipe_nonblocking()?;
        Ok(Waker { read_fd: r, write_fd: w, pending: AtomicBool::new(false), two_fds: true })
    }

    /// The fd the reactor registers for readability.
    pub fn read_fd(&self) -> RawFd {
        self.read_fd
    }

    /// Signal the poller; callable from any thread, lock-free, and safe
    /// to spam — concurrent wakes coalesce into one readable report.
    pub fn wake(&self) {
        if self.two_fds {
            // Relaxed: a stale read at worst writes one extra byte into
            // the pipe or skips a write that another thread already
            // made; both still leave the pipe readable.
            if !self.pending.swap(true, Ordering::Relaxed) {
                // a full pipe is also fine: the reader has a wake pending
                let _ = sys::sys_write(self.write_fd, &[1u8]);
            }
        } else {
            let _ = sys::sys_write(self.write_fd, &1u64.to_ne_bytes());
        }
    }

    /// Consume the pending wake(s) so the fd stops reporting readable.
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        while let Ok(n) = sys::sys_read(self.read_fd, &mut buf) {
            if n < buf.len() {
                break;
            }
        }
        if self.two_fds {
            // Relaxed: ordered after the reads above only loosely; a
            // wake racing this store re-arms the pipe with a fresh byte,
            // so the loop's next wait still sees it.
            self.pending.store(false, Ordering::Relaxed);
        }
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        sys::sys_close(self.read_fd);
        if self.two_fds {
            sys::sys_close(self.write_fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    fn socket_pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    fn poller_round_trip(mut p: Poller) {
        let (mut a, b) = socket_pair();
        b.set_nonblocking(true).unwrap();
        p.register(b.as_raw_fd(), 7, true, false).unwrap();
        let mut evs = Vec::new();
        p.wait(&mut evs, 0).unwrap();
        assert!(evs.is_empty(), "{evs:?}");

        a.write_all(b"ping").unwrap();
        p.wait(&mut evs, 2000).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].token, 7);
        assert!(evs[0].readable);

        // level-triggered: unread input keeps reporting
        p.wait(&mut evs, 0).unwrap();
        assert_eq!(evs.len(), 1, "level-triggered readiness must persist");

        // writable interest on an idle socket reports immediately
        p.modify(b.as_raw_fd(), 7, true, true).unwrap();
        p.wait(&mut evs, 2000).unwrap();
        assert!(evs[0].writable);

        // after the peer closes, read readiness reports EOF (read 0)
        drop(a);
        p.wait(&mut evs, 2000).unwrap();
        assert_eq!(evs.len(), 1);
        assert!(evs[0].readable || evs[0].hangup, "{evs:?}");
        let mut buf = [0u8; 16];
        let mut c = &b;
        assert_eq!(c.read(&mut buf).unwrap(), 4); // the unread "ping"
        assert_eq!(c.read(&mut buf).unwrap(), 0); // then EOF

        p.deregister(b.as_raw_fd()).unwrap();
        p.wait(&mut evs, 0).unwrap();
        assert!(evs.is_empty());
    }

    #[test]
    fn poll_backend_round_trip() {
        poller_round_trip(Poller::new_poll().unwrap());
        assert_eq!(Poller::new_poll().unwrap().backend_name(), "poll");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_backend_round_trip() {
        poller_round_trip(Poller::new().unwrap());
        assert_eq!(Poller::new().unwrap().backend_name(), "epoll");
    }

    fn waker_wakes(w: Waker, mut p: Poller) {
        let w = std::sync::Arc::new(w);
        p.register(w.read_fd(), 1, true, false).unwrap();
        let mut evs = Vec::new();
        p.wait(&mut evs, 0).unwrap();
        assert!(evs.is_empty());

        // wake from another thread interrupts a blocking wait
        let w2 = std::sync::Arc::clone(&w);
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            w2.wake();
            w2.wake(); // coalesces
        });
        p.wait(&mut evs, 5000).unwrap();
        t.join().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].token, 1);

        // drain clears readiness; the next wake re-arms it
        w.drain();
        p.wait(&mut evs, 0).unwrap();
        assert!(evs.is_empty(), "{evs:?}");
        w.wake();
        p.wait(&mut evs, 2000).unwrap();
        assert_eq!(evs.len(), 1);
    }

    #[test]
    fn pipe_waker_wakes_poll_backend() {
        waker_wakes(Waker::new_pipe().unwrap(), Poller::new_poll().unwrap());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn eventfd_waker_wakes_epoll_backend() {
        waker_wakes(Waker::new().unwrap(), Poller::new().unwrap());
    }
}
