//! Multiplexed line-protocol client pump: drives hundreds-to-thousands
//! of concurrent generation streams from **one** thread over a
//! [`Poller`](super::net::Poller), collecting per-stream latency stats.
//! This is the load side of the reactor-front tests and the
//! `concurrency` section of the serving bench — a thread-per-stream
//! client would perturb exactly the scaling property under measurement.

use super::net::{PollEvent, Poller};
use crate::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::os::unix::io::AsRawFd;
use std::time::{Duration, Instant};

/// Everything observed on one stream, client-side.
#[derive(Debug)]
pub struct StreamStats {
    /// Token deltas received.
    pub tokens: usize,
    /// Terminal lines received (`done`/`cancelled`/`error`); the
    /// protocol guarantees exactly one per request.
    pub terminals: usize,
    /// `"done"`, `"cancelled"`, `"shed"`, `"error"`, or `"none"` if the
    /// overall deadline passed first.
    pub outcome: String,
    /// Concatenated token text.
    pub text: String,
    /// Submit-to-first-token latency.
    pub ttft: Option<Duration>,
    /// Worst observed inter-token stall (gap between reads that carried
    /// tokens for this stream; batching makes this a lower bound on
    /// smoothness, an upper-bound stall shows up regardless).
    pub max_gap: Duration,
    /// Submit-to-terminal wall time.
    pub total: Duration,
}

struct MuxConn {
    stream: TcpStream,
    buf: Vec<u8>,
    stats: StreamStats,
    started: Instant,
    last_token_at: Option<Instant>,
    open: bool,
}

/// Build one generation request line.
pub fn request_line(prompt: &str, max_new_tokens: usize, policy: &str) -> String {
    Json::obj(vec![
        ("prompt", Json::str(prompt)),
        ("max_new_tokens", Json::num(max_new_tokens as f64)),
        ("policy", Json::str(policy)),
    ])
    .dump()
}

/// Open one connection per line, write each request, then pump every
/// stream concurrently until all reach a terminal (or the overall
/// deadline passes — remaining streams report outcome `"none"`).
pub fn run_streams(
    addr: &SocketAddr,
    lines: &[String],
    overall_timeout: Duration,
) -> std::io::Result<Vec<StreamStats>> {
    let mut poller = Poller::new()?;
    let mut conns: Vec<MuxConn> = Vec::with_capacity(lines.len());
    for (i, line) in lines.iter().enumerate() {
        let mut stream = connect_retry(addr)?;
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.set_nonblocking(true)?;
        poller.register(stream.as_raw_fd(), i as u64, true, false)?;
        conns.push(MuxConn {
            stream,
            buf: Vec::new(),
            stats: StreamStats {
                tokens: 0,
                terminals: 0,
                outcome: "none".to_string(),
                text: String::new(),
                ttft: None,
                max_gap: Duration::ZERO,
                total: Duration::ZERO,
            },
            started: Instant::now(),
            last_token_at: None,
            open: true,
        });
    }
    let deadline = Instant::now() + overall_timeout;
    let mut open = conns.len();
    let mut events: Vec<PollEvent> = Vec::new();
    while open > 0 && Instant::now() < deadline {
        poller.wait(&mut events, 100)?;
        for i in 0..events.len() {
            let ev = events[i];
            let Some(c) = conns.get_mut(ev.token as usize) else { continue };
            if !c.open {
                continue;
            }
            if read_into(c) {
                process_lines(c);
            }
            if !c.open {
                let _ = poller.deregister(c.stream.as_raw_fd());
                let _ = c.stream.shutdown(std::net::Shutdown::Both);
                open -= 1;
            }
        }
    }
    Ok(conns.into_iter().map(|c| c.stats).collect())
}

/// Connect with a short retry loop: a momentarily full accept backlog
/// (thousands of clients racing one reactor) refuses rather than parks
/// on some stacks.
fn connect_retry(addr: &SocketAddr) -> std::io::Result<TcpStream> {
    let mut last = None;
    for _ in 0..5 {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    Err(last
        .unwrap_or_else(|| std::io::Error::new(std::io::ErrorKind::Other, "connect failed")))
}

/// Drain the socket; returns whether any bytes arrived. EOF or a hard
/// error closes the stream (a missing terminal then stays visible in
/// `terminals`).
fn read_into(c: &mut MuxConn) -> bool {
    let mut chunk = [0u8; 4096];
    let mut got = false;
    loop {
        match c.stream.read(&mut chunk) {
            Ok(0) => {
                c.open = false;
                break;
            }
            Ok(n) => {
                c.buf.extend_from_slice(&chunk[..n]);
                got = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                c.open = false;
                break;
            }
        }
    }
    got
}

fn process_lines(c: &mut MuxConn) {
    let now = Instant::now();
    while let Some(nl) = c.buf.iter().position(|&b| b == b'\n') {
        let line: Vec<u8> = c.buf.drain(..=nl).collect();
        let text = String::from_utf8_lossy(&line);
        let text = text.trim();
        if text.is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(text) else { continue };
        if let Some(t) = j.get("token").as_str() {
            c.stats.tokens += 1;
            c.stats.text.push_str(t);
            let prev = c.last_token_at.unwrap_or(c.started);
            let gap = now.saturating_duration_since(prev);
            if gap > c.stats.max_gap {
                c.stats.max_gap = gap;
            }
            if c.stats.ttft.is_none() {
                c.stats.ttft = Some(now.saturating_duration_since(c.started));
            }
            c.last_token_at = Some(now);
        } else if j.get("done").as_bool() == Some(true) {
            c.stats.terminals += 1;
            c.stats.outcome = "done".to_string();
            c.stats.total = now.saturating_duration_since(c.started);
            c.open = false;
        } else if j.get("cancelled").as_bool() == Some(true) {
            c.stats.terminals += 1;
            c.stats.outcome = "cancelled".to_string();
            c.stats.total = now.saturating_duration_since(c.started);
            c.open = false;
        } else if j.get("error").as_str().is_some() {
            c.stats.terminals += 1;
            c.stats.outcome = if j.get("code").as_str() == Some("shed") {
                "shed".to_string()
            } else {
                "error".to_string()
            };
            c.stats.total = now.saturating_duration_since(c.started);
            c.open = false;
        }
    }
}
