//! Minimal HTTP/1.1 surface for the reactor front: just enough parsing
//! to serve `POST /generate` (SSE token streaming) and `GET /metrics`
//! (scrape JSON) off the same listener as the line protocol, with no
//! crates. Requests are sniffed from the connection's first byte — a
//! JSON-lines client opens with `{`, an HTTP client with a method
//! letter — so both protocols coexist on one port.
//!
//! Responses always carry `Connection: close`: generation streams have
//! no known length (the body ends when the server closes after the
//! terminal SSE event), and one-shot endpoints keep the same lifecycle
//! for simplicity. Clients that want multiplexing use the line protocol.

use crate::util::json::Json;

/// One parsed HTTP request (start line + the headers we act on + body).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpReq {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Total bytes this request consumed from the connection's read
    /// buffer (headers + body), so the caller can drain exactly one
    /// request and leave any pipelined bytes in place.
    pub consumed: usize,
}

/// Incremental parse result over a connection's read buffer.
#[derive(Debug, PartialEq, Eq)]
pub enum HttpParse {
    /// Headers (or declared body) incomplete: keep reading.
    NeedMore,
    /// Malformed request: reply 400 and close.
    Bad(String),
    Req(HttpReq),
}

/// Upper bound on the header block; past this without a blank line the
/// request is malformed (and an unauthenticated client cannot make the
/// server buffer unboundedly).
const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Upper bound on a declared request body (a generation prompt).
const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;

/// Try to parse one HTTP/1.1 request from the front of `buf`.
pub fn parse_http(buf: &[u8]) -> HttpParse {
    let Some(header_end) = find_header_end(buf) else {
        if buf.len() > MAX_HEADER_BYTES {
            return HttpParse::Bad("header block too large".to_string());
        }
        return HttpParse::NeedMore;
    };
    let head = match std::str::from_utf8(&buf[..header_end.start]) {
        Ok(h) => h,
        Err(_) => return HttpParse::Bad("non-UTF-8 header block".to_string()),
    };
    let mut lines = head.lines();
    let Some(start) = lines.next() else {
        return HttpParse::Bad("empty request".to_string());
    };
    let mut parts = start.split_whitespace();
    let (Some(method), Some(path), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return HttpParse::Bad(format!("malformed request line '{start}'"));
    };
    if !version.starts_with("HTTP/1.") {
        return HttpParse::Bad(format!("unsupported version '{version}'"));
    }
    let mut content_length = 0usize;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        if name.trim().eq_ignore_ascii_case("content-length") {
            match value.trim().parse::<usize>() {
                Ok(n) if n <= MAX_BODY_BYTES => content_length = n,
                Ok(_) => return HttpParse::Bad("body too large".to_string()),
                Err(_) => return HttpParse::Bad("bad Content-Length".to_string()),
            }
        }
    }
    let body_start = header_end.end;
    if buf.len() < body_start + content_length {
        return HttpParse::NeedMore;
    }
    HttpParse::Req(HttpReq {
        method: method.to_string(),
        path: path.to_string(),
        body: buf[body_start..body_start + content_length].to_vec(),
        consumed: body_start + content_length,
    })
}

/// Byte range of the header terminator (`\r\n\r\n`, or bare `\n\n` for
/// hand-typed requests): `start` = end of headers, `end` = start of body.
fn find_header_end(buf: &[u8]) -> Option<std::ops::Range<usize>> {
    let crlf = buf.windows(4).position(|w| w == b"\r\n\r\n");
    let lf = buf.windows(2).position(|w| w == b"\n\n");
    match (crlf, lf) {
        (Some(c), Some(l)) if l + 1 < c => Some(l..l + 2),
        (Some(c), _) => Some(c..c + 4),
        (None, Some(l)) => Some(l..l + 2),
        (None, None) => None,
    }
}

/// Full one-shot response (status line + headers + body), ready for the
/// write queue.
pub fn response(status: u16, reason: &str, content_type: &str, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 128);
    out.extend_from_slice(
        format!(
            "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        )
        .as_bytes(),
    );
    out.extend_from_slice(body);
    out
}

/// One-shot JSON response.
pub fn json_response(status: u16, reason: &str, j: &Json) -> Vec<u8> {
    let mut body = j.dump().into_bytes();
    body.push(b'\n');
    response(status, reason, "application/json", &body)
}

/// Response head for an SSE stream; the body is a sequence of
/// [`sse_event`] frames and the stream ends when the connection closes.
pub fn sse_headers() -> Vec<u8> {
    b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
      Cache-Control: no-cache\r\nConnection: close\r\n\r\n"
        .to_vec()
}

/// One SSE frame carrying a JSON payload.
pub fn sse_event(j: &Json) -> Vec<u8> {
    format!("data: {}\n\n", j.dump()).into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        match parse_http(raw) {
            HttpParse::Req(r) => {
                assert_eq!(r.method, "POST");
                assert_eq!(r.path, "/generate");
                assert_eq!(r.body, b"hello");
                assert_eq!(r.consumed, raw.len());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_get_without_body_and_leaves_pipelined_bytes() {
        let raw = b"GET /metrics HTTP/1.1\r\n\r\nGET /next";
        match parse_http(raw) {
            HttpParse::Req(r) => {
                assert_eq!(r.method, "GET");
                assert_eq!(r.path, "/metrics");
                assert!(r.body.is_empty());
                assert_eq!(&raw[r.consumed..], b"GET /next");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn case_insensitive_content_length_and_bare_lf() {
        let raw = b"POST /generate HTTP/1.0\ncontent-LENGTH: 2\n\nok";
        match parse_http(raw) {
            HttpParse::Req(r) => assert_eq!(r.body, b"ok"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn incomplete_requests_ask_for_more() {
        assert_eq!(parse_http(b"POST /gen"), HttpParse::NeedMore);
        assert_eq!(
            parse_http(b"POST /g HTTP/1.1\r\nContent-Length: 9\r\n\r\nshort"),
            HttpParse::NeedMore
        );
    }

    #[test]
    fn malformed_requests_are_rejected() {
        assert!(matches!(
            parse_http(b"NOPE\r\n\r\n"),
            HttpParse::Bad(_)
        ));
        assert!(matches!(
            parse_http(b"GET /x SPDY/3\r\n\r\n"),
            HttpParse::Bad(_)
        ));
        assert!(matches!(
            parse_http(b"POST /g HTTP/1.1\r\nContent-Length: zap\r\n\r\n"),
            HttpParse::Bad(_)
        ));
        let huge = vec![b'a'; MAX_HEADER_BYTES + 2];
        assert!(matches!(parse_http(&huge), HttpParse::Bad(_)));
    }

    #[test]
    fn response_builders_frame_correctly() {
        let r = response(404, "Not Found", "application/json", b"{}");
        let s = String::from_utf8(r).unwrap();
        assert!(s.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with("\r\n\r\n{}"));

        let h = String::from_utf8(sse_headers()).unwrap();
        assert!(h.contains("text/event-stream"));
        assert!(h.ends_with("\r\n\r\n"));

        let ev = sse_event(&Json::obj(vec![("token", Json::str("a"))]));
        let ev = String::from_utf8(ev).unwrap();
        assert!(ev.starts_with("data: {"));
        assert!(ev.ends_with("}\n\n"));
    }
}
