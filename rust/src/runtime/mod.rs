//! PJRT runtime: loads AOT HLO-text artifacts and executes them on the
//! CPU client (`xla` crate / xla_extension 0.5.1). The interchange format
//! is HLO *text* — jax >= 0.5 emits 64-bit instruction ids in serialized
//! protos that this XLA rejects; the text parser reassigns ids.
//!
//! Executables are compiled once on first use and cached; shape buckets
//! (batch, active-set size M, prefill length S) are resolved here so the
//! engine just asks for "attention with B sequences and >= n active
//! tokens".

use crate::model::Manifest;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Literal constructors for the shapes this runtime feeds.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_f32: {} elements for shape {:?}", data.len(), shape);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_i32: {} elements for shape {:?}", data.len(), shape);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_i32_scalar(v: i32) -> Literal {
    Literal::scalar(v)
}

/// The PJRT runtime with a compiled-executable cache.
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, PjRtLoadedExecutable>>,
    /// Executions per program (visible in `lychee stats` / benches).
    pub exec_counts: RefCell<HashMap<String, u64>>,
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            exec_counts: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) a program by manifest name.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling program {name}"))?;
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of programs (warmup; avoids first-request jitter).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }

    /// Execute a program; returns its outputs as literals (tuple outputs
    /// are decomposed using the manifest's `nouts`).
    pub fn exec(&self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        self.ensure_compiled(name)?;
        *self.exec_counts.borrow_mut().entry(name.to_string()).or_insert(0) += 1;
        let exes = self.exes.borrow();
        let exe = exes.get(name).unwrap();
        let meta = self.manifest.program(name)?;
        if args.len() != meta.args.len() {
            bail!("{name}: {} args given, {} expected", args.len(), meta.args.len());
        }
        let result = exe
            .execute::<&Literal>(args)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {name} output"))?;
        if meta.tuple {
            let parts = lit.to_tuple().with_context(|| format!("{name}: untuple"))?;
            if parts.len() != meta.nouts {
                bail!("{name}: got {} outputs, manifest says {}", parts.len(), meta.nouts);
            }
            Ok(parts)
        } else {
            Ok(vec![lit])
        }
    }

    // ---- bucket resolution -------------------------------------------

    /// Smallest compiled batch bucket >= `b`.
    pub fn batch_bucket(&self, b: usize) -> Result<usize> {
        self.manifest
            .buckets
            .batch
            .iter()
            .copied()
            .filter(|&x| x >= b)
            .min()
            .with_context(|| format!("no batch bucket >= {b}"))
    }

    /// Smallest compiled attention M bucket >= `m` for batch bucket `b`.
    pub fn attn_bucket(&self, b: usize, m: usize) -> Result<usize> {
        let list = if b == 1 {
            &self.manifest.buckets.attn_m_b1
        } else {
            &self.manifest.buckets.attn_m_bn
        };
        list.iter()
            .copied()
            .filter(|&x| x >= m)
            .min()
            .with_context(|| format!("no attn bucket >= {m} for batch {b}"))
    }

    /// Smallest compiled prefill S bucket >= `s`.
    pub fn prefill_bucket(&self, s: usize) -> Result<usize> {
        self.manifest
            .buckets
            .prefill_s
            .iter()
            .copied()
            .filter(|&x| x >= s)
            .min()
            .with_context(|| format!("prompt of {s} tokens exceeds largest prefill bucket"))
    }

    /// Largest prefill bucket (coordinator admission control).
    pub fn max_prompt(&self) -> usize {
        self.manifest.buckets.prefill_s.iter().copied().max().unwrap_or(0)
    }
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !p.join("manifest.json").exists() {
            return None;
        }
        let m = Manifest::load(&p).unwrap();
        Some(Runtime::new(m).unwrap())
    }

    #[test]
    fn literal_builders_validate_shape() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert!(lit_i32(&[1], &[2]).is_err());
    }

    #[test]
    fn bucket_resolution() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.batch_bucket(1).unwrap(), 1);
        assert_eq!(rt.batch_bucket(3).unwrap(), 4);
        assert_eq!(rt.batch_bucket(5).unwrap(), 8);
        assert!(rt.batch_bucket(9).is_err());
        assert_eq!(rt.attn_bucket(1, 100).unwrap(), 128);
        assert_eq!(rt.attn_bucket(1, 1025).unwrap(), 2048);
        assert_eq!(rt.attn_bucket(4, 1500).unwrap(), 2048);
        assert!(rt.attn_bucket(4, 64000).is_err());
        assert_eq!(rt.attn_bucket(1, 64000).unwrap(), 65536);
        assert_eq!(rt.prefill_bucket(10).unwrap(), 128);
        assert_eq!(rt.prefill_bucket(600).unwrap(), 2048);
        assert_eq!(rt.max_prompt(), 2048);
    }

    #[test]
    fn embed_program_runs_and_matches_weights() {
        let Some(rt) = runtime() else { return };
        let w = crate::model::Weights::load(&rt.manifest).unwrap();
        let emb = w.get("emb");
        let d = rt.manifest.dims.d_model;
        let args = vec![
            lit_f32(emb, &[256, d]).unwrap(),
            lit_i32(&[65], &[1]).unwrap(), // token 'A'
        ];
        let argrefs: Vec<&Literal> = args.iter().collect();
        let out = rt.exec("embed_b1", &argrefs).unwrap();
        let x = to_f32_vec(&out[0]).unwrap();
        assert_eq!(x.len(), d);
        let expect = &emb[65 * d..66 * d];
        for (a, b) in x.iter().zip(expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn attn_kernel_matches_rust_oracle() {
        // The PJRT-executed Pallas kernel vs the pure-Rust oracle: the
        // cross-layer correctness anchor for the whole serving stack.
        let Some(rt) = runtime() else { return };
        let dims = rt.manifest.dims.clone();
        let (h, dh) = (dims.heads, dims.head_dim);
        let m = 128usize;
        let mut rng = crate::util::rng::Rng::new(42);
        let q = rng.normal_vec(h * dh);
        let k = rng.normal_vec(m * h * dh);
        let v = rng.normal_vec(m * h * dh);
        let mut mask = vec![0.0f32; m];
        for slot in mask.iter_mut().take(70) {
            *slot = 1.0;
        }
        let args = vec![
            lit_f32(&q, &[1, h, dh]).unwrap(),
            lit_f32(&k, &[1, m, h, dh]).unwrap(),
            lit_f32(&v, &[1, m, h, dh]).unwrap(),
            lit_f32(&mask, &[1, m]).unwrap(),
        ];
        let argrefs: Vec<&Literal> = args.iter().collect();
        let out = to_f32_vec(&rt.exec("attn_b1_m128", &argrefs).unwrap()[0]).unwrap();
        assert_eq!(out.len(), h * dh);

        // oracle: per-head attention over the 70 valid tokens
        let scale = 1.0 / (dh as f32).sqrt();
        for head in 0..h {
            let qh: Vec<f32> = q[head * dh..(head + 1) * dh].to_vec();
            let mut scores = Vec::new();
            for t in 0..70 {
                let kh = &k[(t * h + head) * dh..(t * h + head + 1) * dh];
                scores.push(crate::linalg::dot(&qh, kh) * scale);
            }
            crate::linalg::softmax(&mut scores);
            let mut expect = vec![0.0f32; dh];
            for (t, &w) in scores.iter().enumerate() {
                let vh = &v[(t * h + head) * dh..(t * h + head + 1) * dh];
                crate::linalg::axpy(&mut expect, w, vh);
            }
            for (a, b) in out[head * dh..(head + 1) * dh].iter().zip(&expect) {
                assert!((a - b).abs() < 1e-4, "head {head}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn exec_counts_tracked() {
        let Some(rt) = runtime() else { return };
        let w = crate::model::Weights::load(&rt.manifest).unwrap();
        let d = rt.manifest.dims.d_model;
        let args = vec![
            lit_f32(w.get("emb"), &[256, d]).unwrap(),
            lit_i32(&[1], &[1]).unwrap(),
        ];
        let argrefs: Vec<&Literal> = args.iter().collect();
        rt.exec("embed_b1", &argrefs).unwrap();
        rt.exec("embed_b1", &argrefs).unwrap();
        assert_eq!(rt.exec_counts.borrow()["embed_b1"], 2);
        assert_eq!(rt.compiled_count(), 1);
    }
}
