//! Exact attention oracle (pure Rust, numerically careful).
//!
//! Serves three roles: (1) ground truth for the Recall Rate metric
//! (paper Table 3: fraction of the true top-k attention tokens a policy
//! retrieves within budget), (2) correctness oracle for the PJRT/Pallas
//! kernel in integration tests, (3) the scoring core that eviction
//! baselines (H2O, RaaS) feed on.

use crate::index::reps::{for_each_key, KeySource};
use crate::linalg;

/// Softmax attention weights of query `q` over keys `[0, n)` from a key
/// source (head-merged dim-d rows), written into `out` (cleared first).
/// `scale` is usually 1/sqrt(head_dim) — on merged rows the per-head
/// softmax structure is collapsed; for oracle purposes the merged form
/// preserves the ranking the index sees. Flat key sources score with one
/// blocked GEMV; paged sources fall back to per-row dots.
pub fn attention_weights_into(
    q: &[f32],
    keys: &dyn KeySource,
    n: usize,
    scale: f32,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.resize(n, 0.0);
    match keys.as_rows() {
        Some(rows) => linalg::matvec(&rows[..n * keys.dim()], keys.dim(), q, out),
        None => {
            // paged (possibly quantized) source: per-row dots, widening
            // through for_each_key's reused buffer when storage is not f32
            for_each_key(keys, 0, n, |t, k| out[t] = linalg::dot(q, k));
        }
    }
    for s in out.iter_mut() {
        *s *= scale;
    }
    linalg::softmax(out);
}

/// Allocating wrapper over [`attention_weights_into`].
pub fn attention_weights(q: &[f32], keys: &dyn KeySource, n: usize, scale: f32) -> Vec<f32> {
    let mut out = Vec::new();
    attention_weights_into(q, keys, n, scale, &mut out);
    out
}

/// Renormalized softmax weights over an arbitrary token subset (the
/// sparse path), written into `out` aligned with `tokens` (cleared
/// first). Allocation-free when `out` has capacity — the eviction
/// baselines call this every decode step.
pub fn sparse_attention_weights_into(
    q: &[f32],
    keys: &dyn KeySource,
    tokens: &[usize],
    scale: f32,
    out: &mut Vec<f32>,
) {
    out.clear();
    // f32-backed sources stay allocation-free (zero-copy borrows); a
    // quantized source widens each subset row through one buffer,
    // allocated lazily on first non-borrowable row
    let mut tmp: Vec<f32> = Vec::new();
    for &t in tokens {
        let s = match keys.try_key(t) {
            Some(k) => linalg::dot(q, k),
            None => {
                if tmp.is_empty() {
                    tmp.resize(keys.dim(), 0.0);
                }
                keys.key_into(t, &mut tmp);
                linalg::dot(q, &tmp)
            }
        };
        out.push(s * scale);
    }
    linalg::softmax(out);
}

/// Attention weights over an arbitrary token subset (the sparse path);
/// returns (token, weight) pairs with weights renormalized over the set.
pub fn sparse_attention_weights(
    q: &[f32],
    keys: &dyn KeySource,
    tokens: &[usize],
    scale: f32,
) -> Vec<(usize, f32)> {
    let mut scores = Vec::new();
    sparse_attention_weights_into(q, keys, tokens, scale, &mut scores);
    tokens.iter().copied().zip(scores).collect()
}

/// Ground-truth top-k attention token ids (descending weight).
pub fn top_attention_tokens(q: &[f32], keys: &dyn KeySource, n: usize, k: usize, scale: f32) -> Vec<usize> {
    let w = attention_weights(q, keys, n, scale);
    linalg::top_k(&w, k)
}

/// Weighted value sum using full attention: `out = Σ softmax(q·K) · V`.
/// `values` indexed like `keys`. The reference output for kernel checks.
pub fn full_attention_output(
    q: &[f32],
    keys: &dyn KeySource,
    values: &dyn KeySource,
    n: usize,
    scale: f32,
) -> Vec<f32> {
    let w = attention_weights(q, keys, n, scale);
    let mut out = vec![0.0f32; values.dim()];
    for_each_key(values, 0, n, |t, v| linalg::axpy(&mut out, w[t], v));
    out
}

/// Sparse attention output over a token subset.
pub fn sparse_attention_output(
    q: &[f32],
    keys: &dyn KeySource,
    values: &dyn KeySource,
    tokens: &[usize],
    scale: f32,
) -> Vec<f32> {
    let mut out = vec![0.0f32; values.dim()];
    if tokens.is_empty() {
        return out;
    }
    // lazy dequant buffer: f32-backed value sources never allocate it
    let mut tmp: Vec<f32> = Vec::new();
    for (t, w) in sparse_attention_weights(q, keys, tokens, scale) {
        match values.try_key(t) {
            Some(v) => linalg::axpy(&mut out, w, v),
            None => {
                if tmp.is_empty() {
                    tmp.resize(values.dim(), 0.0);
                }
                values.key_into(t, &mut tmp);
                linalg::axpy(&mut out, w, &tmp);
            }
        }
    }
    out
}

/// Recall Rate (paper Table 3): |retrieved ∩ true-top-k| / k where
/// true-top-k are the ground-truth highest-attention tokens.
pub fn recall_rate(
    q: &[f32],
    keys: &dyn KeySource,
    n: usize,
    retrieved: &[usize],
    k: usize,
    scale: f32,
) -> f64 {
    let k = k.min(n);
    if k == 0 {
        return 1.0;
    }
    let truth = top_attention_tokens(q, keys, n, k, scale);
    let set: std::collections::HashSet<usize> = retrieved.iter().copied().collect();
    truth.iter().filter(|t| set.contains(t)).count() as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::reps::FlatKeys;
    use crate::util::rng::Rng;

    #[test]
    fn weights_sum_to_one() {
        let mut rng = Rng::new(0);
        let data = rng.normal_vec(32 * 8);
        let keys = FlatKeys::new(&data, 8);
        let q = rng.normal_vec(8);
        let w = attention_weights(&q, &keys, 32, 0.35);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn aligned_key_dominates() {
        let mut data = vec![0.0f32; 16 * 4];
        data[5 * 4] = 5.0; // token 5 = [5,0,0,0]
        let keys = FlatKeys::new(&data, 4);
        let q = [3.0, 0.0, 0.0, 0.0];
        let w = attention_weights(&q, &keys, 16, 1.0);
        assert_eq!(linalg::argmax(&w), 5);
        assert!(w[5] > 0.9);
    }

    #[test]
    fn sparse_weights_renormalize() {
        let mut rng = Rng::new(1);
        let data = rng.normal_vec(20 * 4);
        let keys = FlatKeys::new(&data, 4);
        let q = rng.normal_vec(4);
        let subset = vec![1, 5, 9];
        let sw = sparse_attention_weights(&q, &keys, &subset, 0.5);
        let total: f32 = sw.iter().map(|(_, w)| w).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn sparse_equals_full_when_subset_is_everything() {
        let mut rng = Rng::new(2);
        let kd = rng.normal_vec(12 * 4);
        let vd = rng.normal_vec(12 * 4);
        let keys = FlatKeys::new(&kd, 4);
        let values = FlatKeys::new(&vd, 4);
        let q = rng.normal_vec(4);
        let full = full_attention_output(&q, &keys, &values, 12, 0.5);
        let all: Vec<usize> = (0..12).collect();
        let sparse = sparse_attention_output(&q, &keys, &values, &all, 0.5);
        for (a, b) in full.iter().zip(&sparse) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn recall_rate_bounds() {
        let mut rng = Rng::new(3);
        let data = rng.normal_vec(64 * 8);
        let keys = FlatKeys::new(&data, 8);
        let q = rng.normal_vec(8);
        let all: Vec<usize> = (0..64).collect();
        assert!((recall_rate(&q, &keys, 64, &all, 16, 0.35) - 1.0).abs() < 1e-12);
        assert_eq!(recall_rate(&q, &keys, 64, &[], 16, 0.35), 0.0);
        let truth = top_attention_tokens(&q, &keys, 64, 16, 0.35);
        let half = &truth[..8];
        assert!((recall_rate(&q, &keys, 64, half, 16, 0.35) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_subset_gives_zero_output() {
        let data = vec![1.0f32; 4];
        let keys = FlatKeys::new(&data, 4);
        let out = sparse_attention_output(&[1.0, 0.0, 0.0, 0.0], &keys, &keys, &[], 1.0);
        assert_eq!(out, vec![0.0; 4]);
    }
}
