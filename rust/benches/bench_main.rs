//! Benchmark harness (criterion is unavailable offline; this is a
//! self-contained warmup+iterations harness with mean/p50/p99 reporting).
//!
//! One bench section per paper table/figure plus the design-choice
//! ablations called out in DESIGN.md:
//!   retrieval_micro      — UB-pruned hierarchical search vs flat scan
//!   ablation_tiers       — 3-tier vs 2-tier (flat clusters)
//!   ablation_update      — lazy graft vs periodic full re-clustering
//!   kmeans               — spherical k-means build cost
//!   chunking             — segmentation throughput
//!   kvcache_gather       — paged-cache gather into budget buffers
//!   simd                 — scalar vs AVX2 kernels (dot / matvec)
//!   retrieval_json       — machine-readable BENCH_retrieval.json:
//!                          ns/token select per policy per context size,
//!                          SoA+SIMD vs seed-style scalar scoring at 32k,
//!                          serial-vs-parallel batch retrieval, and the
//!                          mixed-precision sweep (select+gather per
//!                          kv/index precision, gather GB/token, arena
//!                          capacity at fixed kv_pool_mb;
//!                          BENCH_PRECISION=f32|f16|i8 narrows it),
//!                          and the dense-vs-blockmax select sweep
//!                          (32k->1M tokens: per-path µs, blocks-scanned
//!                          fraction, fitted growth exponent)
//!   serving_json         — machine-readable BENCH_serving.json: mixed
//!                          long+short load through the real coordinator
//!                          (sim engine), chunked vs monolithic prefill —
//!                          TTFT/TPOT p50+p99 per class and the worst
//!                          decode stall the short sequences observed —
//!                          plus the prefix_reuse section (multiturn
//!                          workload, radix-on vs radix-off at 8/32/128
//!                          sessions: later-turn TTFT, prefill chunks,
//!                          hit-rate, shared-bytes dedup ratio)
//!                          and the cluster section (1/2/4-shard sweep
//!                          over the sharded serving tier: TTFT/TPOT,
//!                          throughput, radix hit-rate vs shard count;
//!                          with `--features failpoints` also a seeded
//!                          shard-kill failover run reporting the worst
//!                          client-visible stall as recovery latency)
//!   fig4_tpot            — end-to-end decode TPOT (engine + PJRT)
//!   serving_throughput   — batched coordinator throughput
//!
//! Run with `cargo bench` (all) or `cargo bench -- <filter>`.
//! `BENCH_SMOKE=1` shrinks iteration counts/contexts for CI smoke runs;
//! `BENCH_JSON_PATH` / `BENCH_SERVING_JSON_PATH` override where the
//! `*_json` sections write their files (defaults: `BENCH_retrieval.json`
//! / `BENCH_serving.json` in the current directory).

use lychee::chunking::{Chunk, Chunker, FixedSizeChunker, StructureAwareChunker};
use lychee::config::{Config, LycheeConfig};
use lychee::index::hierarchy::{HierarchicalIndex, IndexParams};
use lychee::index::kmeans::spherical_kmeans;
use lychee::index::reps::FlatKeys;
use lychee::kvcache::KvCache;
use lychee::linalg;
use lychee::sparse::{make_policy, Ctx, SelectScratch};
use lychee::util::rng::Rng;
use lychee::util::stats::Summary;
use lychee::workloads::trace::prompt_text;

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

fn bench_quiet<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = std::time::Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    Summary::of(&samples)
}

fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> Summary {
    let s = bench_quiet(warmup, iters, f);
    println!(
        "{name:<44} mean {m:>10.1} µs   p50 {p50:>10.1}   p99 {p99:>10.1}   n={n}",
        m = s.mean,
        p50 = s.p50,
        p99 = s.p99,
        n = s.n
    );
    s
}

fn filter_match(name: &str) -> bool {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    args.is_empty() || args.iter().any(|a| name.contains(a.as_str()))
}

fn section(name: &str) -> bool {
    let run = filter_match(name);
    if run {
        println!("\n--- {name} ---");
    }
    run
}

fn main() {
    println!("lychee bench harness (custom; see rust/benches/bench_main.rs)");

    let mut rng = Rng::new(0xBE9C4);
    let d = 32;

    // shared corpus: 32k tokens of mixed text + synthetic keys
    let n = 32 * 1024;
    let text = prompt_text(n, 1);
    let keys: Vec<f32> = rng.normal_vec(n * d);
    let src = FlatKeys::new(&keys, d);
    let chunker = StructureAwareChunker::new(16, 64);
    let spans = chunker.chunk(&text);

    if section("chunking") {
        bench("structure-aware chunk 32k bytes", 2, 20, || {
            std::hint::black_box(chunker.chunk(&text));
        });
        let fx = FixedSizeChunker::new(48);
        bench("fixed-48 chunk 32k bytes", 2, 20, || {
            std::hint::black_box(fx.chunk(&text));
        });
    }

    if section("kmeans") {
        let reps: Vec<f32> = rng.normal_vec(1000 * d);
        bench("spherical k-means 1000x32 k=500 it=10", 1, 10, || {
            std::hint::black_box(spherical_kmeans(&reps, d, 500, 10, 1));
        });
    }

    let index = HierarchicalIndex::build(&src, &spans, IndexParams::default());
    println!(
        "index: {} chunks, {} clusters, {} units over {} tokens",
        index.num_chunks(),
        index.num_clusters(),
        index.num_units(),
        index.num_tokens()
    );

    if section("retrieval_micro") {
        let q = rng.unit_vec(d);
        bench("hierarchical UB search (kg=8,kc=64,B=960)", 5, 200, || {
            std::hint::black_box(index.select_tokens(&q, 8, 64, 960));
        });
        bench("flat chunk scan (ablation_ub)", 5, 200, || {
            std::hint::black_box(index.select_tokens_flat(&q, 960));
        });
    }

    if section("ablation_tiers") {
        let q = rng.unit_vec(d);
        // 2-tier = skip coarse pruning: kg = all units
        bench("3-tier (kg=8)", 5, 200, || {
            std::hint::black_box(index.select_tokens(&q, 8, 64, 960));
        });
        let all_units = index.num_units();
        bench("2-tier (kg=all units)", 5, 200, || {
            std::hint::black_box(index.select_tokens(&q, all_units, 64, 960));
        });
    }

    if section("ablation_update") {
        let mut idx = index.clone();
        let mut r2 = Rng::new(7);
        let mut next = n;
        bench("lazy graft (1 dynamic chunk)", 5, 200, || {
            idx.graft_rep(
                lychee::chunking::Chunk { start: next, len: 48 },
                r2.unit_vec(d),
            );
            next += 48;
        });
        let mut idx2 = index.clone();
        bench("full re-cluster (the avoided cost)", 0, 3, || {
            idx2.recluster();
        });
    }

    if section("batch_retrieval") {
        // The serving hot path in isolation: per-step policy select() +
        // arena gather() for a decode batch, serial loop vs the scoped-
        // thread sharding the engine uses. Same caches, same policies,
        // same queries — only the scheduling differs. Throughput should
        // improve with batch size >= 4 on multi-core hosts.
        use lychee::engine::LayerKeys;
        use lychee::kvcache::PagePool;
        use lychee::sparse::Policy;
        use lychee::util::threadpool::scoped_map_mut;
        use std::sync::Arc;

        let d2 = 64usize;
        let ctx_tokens = 8 * 1024;
        let cfg = LycheeConfig::default();
        let pool = PagePool::unbounded();

        struct BatchSeq {
            kv: KvCache,
            policy: Box<dyn Policy>,
            text: Vec<u8>,
            q: Vec<f32>,
        }

        let mk_seq = |i: usize| -> BatchSeq {
            let mut rng = Rng::new(0xBA7C4 + i as u64);
            let mut kv = KvCache::with_pool(1, 1, d2, Arc::clone(&pool));
            let text = prompt_text(ctx_tokens, i as u64);
            for _ in 0..ctx_tokens {
                let kr = rng.normal_vec(d2);
                kv.append_token(&[&kr], &[&kr]).unwrap();
            }
            let mut policy = make_policy("lychee", &cfg, 1, 4).unwrap();
            {
                let keys = LayerKeys { cache: &kv, layer: 0, n: ctx_tokens };
                policy.build(&Ctx { keys: &keys, text: &text, n: ctx_tokens });
            }
            BatchSeq { kv, policy, text, q: rng.normal_vec(d2) }
        };

        let m = 2048usize;
        let row = d2;
        for bsz in [1usize, 2, 4, 8] {
            let mut batch: Vec<BatchSeq> = (0..bsz).map(|i| mk_seq(i)).collect();
            let mut kb = vec![0.0f32; bsz * m * row];
            let mut vb = vec![0.0f32; bsz * m * row];
            let mut mb = vec![0.0f32; bsz * m];

            bench(&format!("retrieval+gather serial   b={bsz}"), 2, 15, || {
                for i in 0..bsz {
                    let sel = {
                        let s = &mut batch[i];
                        let keys = LayerKeys { cache: &s.kv, layer: 0, n: ctx_tokens };
                        let ctx = Ctx { keys: &keys, text: &s.text, n: ctx_tokens };
                        s.policy.select(&ctx, &s.q, ctx_tokens)
                    };
                    batch[i].kv.gather_into(
                        0,
                        &sel,
                        &mut kb[i * m * row..(i + 1) * m * row],
                        &mut vb[i * m * row..(i + 1) * m * row],
                        &mut mb[i * m..(i + 1) * m],
                    );
                }
                std::hint::black_box(&kb);
            });

            bench(&format!("retrieval+gather parallel b={bsz}"), 2, 15, || {
                let sels: Vec<Vec<usize>> = scoped_map_mut(&mut batch, bsz, |_i, s| {
                    let keys = LayerKeys { cache: &s.kv, layer: 0, n: ctx_tokens };
                    let ctx = Ctx { keys: &keys, text: &s.text, n: ctx_tokens };
                    s.policy.select(&ctx, &s.q, ctx_tokens)
                });
                // same batched-gather entry point the engine's decode
                // loop uses, so this measures the real serving path
                let caches: Vec<&KvCache> = batch.iter().map(|s| &s.kv).collect();
                lychee::kvcache::gather_batch_into(
                    &caches, 0, &sels, m, &mut kb, &mut vb, &mut mb, bsz,
                );
                std::hint::black_box(&kb);
            });
        }
    }

    if section("kvcache_gather") {
        let mut cache = KvCache::new(4, 4, 32);
        let mut r3 = Rng::new(9);
        for _ in 0..16 * 1024 {
            let kr: Vec<Vec<f32>> = (0..4).map(|_| r3.normal_vec(128)).collect();
            let krr: Vec<&[f32]> = kr.iter().map(|r| r.as_slice()).collect();
            cache.append_token(&krr, &krr).unwrap();
        }
        let idx: Vec<usize> = (0..1024).map(|i| (i * 16) % (16 * 1024)).collect();
        let (mut k, mut v, mut m) = (Vec::new(), Vec::new(), Vec::new());
        bench("gather 1024 rows into 1024-bucket", 5, 200, || {
            cache.gather(0, &idx, 1024, &mut k, &mut v, &mut m);
            std::hint::black_box(&k);
        });
    }

    if section("policies_select") {
        let cfg = LycheeConfig::default();
        for name in ["lychee", "quest", "clusterkv", "arkvale", "shadowkv"] {
            let mut p = make_policy(name, &cfg, 1, 4).unwrap();
            let ctx = Ctx { keys: &src, text: &text, n };
            p.build(&ctx);
            let q = rng.normal_vec(d);
            bench(&format!("{name} select @32k budget=1024"), 3, 100, || {
                std::hint::black_box(p.select(&ctx, &q, n));
            });
        }
    }

    if section("simd") {
        // scalar reference vs the dispatched (AVX2 where available)
        // kernels on scoring-shaped inputs
        println!("kernel backend: {}", linalg::simd::backend().name());
        let d2 = 64usize;
        let rows = 683usize; // ~32k tokens / 48-byte chunks
        let mut r = Rng::new(0x51D);
        let mat: Vec<f32> = r.normal_vec(rows * d2);
        let q = r.normal_vec(d2);
        let mut out = vec![0.0f32; rows];
        bench("scalar matvec 683x64", 5, 200, || {
            linalg::simd::scalar_matvec(&mat, d2, &q, &mut out);
            std::hint::black_box(&out);
        });
        bench("dispatched matvec 683x64", 5, 200, || {
            linalg::matvec(&mat, d2, &q, &mut out);
            std::hint::black_box(&out);
        });
        let a = r.normal_vec(4096);
        let b = r.normal_vec(4096);
        bench("scalar dot 4096", 5, 500, || {
            std::hint::black_box(linalg::simd::scalar_dot(&a, &b));
        });
        bench("dispatched dot 4096", 5, 500, || {
            std::hint::black_box(linalg::dot(&a, &b));
        });
    }

    if section("retrieval_json") {
        let json = retrieval_json_section();
        let path = std::env::var("BENCH_JSON_PATH")
            .unwrap_or_else(|_| "BENCH_retrieval.json".to_string());
        match std::fs::write(&path, &json) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                // fail the run loudly: CI's artifact upload depends on
                // this file existing, and a green run without it would
                // silently drop the perf trajectory
                eprintln!("FAILED writing {path}: {e}");
                std::process::exit(1);
            }
        }
    }

    if section("serving_json") {
        let json = serving_json_section();
        let path = std::env::var("BENCH_SERVING_JSON_PATH")
            .unwrap_or_else(|_| "BENCH_serving.json".to_string());
        match std::fs::write(&path, &json) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("FAILED writing {path}: {e}");
                std::process::exit(1);
            }
        }
    }

    // engine benches need artifacts
    let mut cfg = Config::new();
    if !std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        let alt = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if alt.join("manifest.json").exists() {
            cfg.artifacts_dir = alt.to_str().unwrap().to_string();
        } else {
            println!("\n(artifacts missing: skipping fig4_tpot / serving benches)");
            return;
        }
    }

    if section("fig4_tpot") {
        let engine = lychee::engine::Engine::load(cfg.clone()).unwrap();
        let sampling = lychee::engine::Sampling::default();
        for ctx_len in [8 * 1024usize, 32 * 1024] {
            for policy in ["full", "lychee"] {
                let mut seq = engine.synth_sequence(1, ctx_len, policy, 3).unwrap();
                engine.decode_step(&mut seq, &sampling).unwrap();
                bench(
                    &format!("decode step {policy} @{}k", ctx_len / 1024),
                    1,
                    5,
                    || {
                        engine.decode_step(&mut seq, &sampling).unwrap();
                    },
                );
            }
        }
    }

    if section("serving_throughput") {
        let (handle, metrics, join) = lychee::coordinator::spawn(cfg).unwrap();
        let t0 = std::time::Instant::now();
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            rxs.push(
                handle
                    .submit(lychee::coordinator::Request {
                        id: i,
                        prompt: prompt_text(256, i),
                        max_new_tokens: 16,
                        policy: "lychee".into(),
                        deadline_ms: None,
                        carried_tokens: 0,
                    })
                    .unwrap(),
            );
        }
        for rx in rxs {
            for ev in rx {
                if matches!(ev, lychee::coordinator::Event::Done(_) | lychee::coordinator::Event::Error(_)) {
                    break;
                }
            }
        }
        let el = t0.elapsed().as_secs_f64();
        let m = metrics.lock().unwrap();
        println!(
            "serving: 8 reqs x 16 toks in {el:.2}s -> {:.1} tok/s (p50 TPOT {:.1} ms)",
            m.throughput_tokens_per_s(el),
            m.tpot_us.quantile(0.5) / 1e3
        );
        drop(m);
        handle.shutdown();
        let _ = join.join();
    }

    println!("\nbench harness done.");
}

/// The serving-trajectory section: mixed long+short load through the
/// real coordinator (sim engine — no artifacts needed), chunked vs
/// monolithic prefill, rendered as `BENCH_serving.json` (schema in
/// EXPERIMENTS.md §Serving). Four short interactive sequences decode
/// while one long prompt prefills mid-stream; per-class TTFT/TPOT
/// p50+p99 plus the worst inter-token stall the shorts observed.
fn serving_json_section() -> String {
    use lychee::coordinator::{spawn_with, Event, Request};
    use lychee::engine::sim::{SimConfig, SimEngine};
    use lychee::util::stats::percentile;

    let smoke = smoke();
    let long_prompt_tokens: usize = if smoke { 4 * 1024 } else { 16 * 1024 };
    let short_prompt_tokens: usize = 512;
    let short_max_new: usize = if smoke { 64 } else { 256 };
    let chunk_tokens: usize = 512;
    let prefill_us_per_token: u64 = if smoke { 10 } else { 30 };

    let mut mode_rows = Vec::new();
    for (mode, chunk) in [("chunked", chunk_tokens), ("monolithic", 0usize)] {
        let mut cfg = Config::new();
        cfg.serving.prefill_chunk_tokens = chunk;
        cfg.serving.max_new_tokens = short_max_new.max(8);
        let sim = SimConfig {
            prefill_us_per_token,
            ..SimConfig::default()
        };
        let engine_cfg = cfg.clone();
        let (handle, metrics, join) =
            spawn_with(cfg, move || Ok(SimEngine::new(engine_cfg, sim))).unwrap();

        // 4 short interactive sequences, tracked token-by-token
        let mut short_threads = Vec::new();
        for i in 0..4u64 {
            let rx = handle
                .submit(Request {
                    id: i,
                    prompt: prompt_text(short_prompt_tokens, i),
                    max_new_tokens: short_max_new,
                    policy: "lychee".into(),
                    deadline_ms: None,
                    carried_tokens: 0,
                })
                .unwrap();
            short_threads.push(std::thread::spawn(move || {
                // gaps measured only BETWEEN tokens: the first token's
                // latency is TTFT (reported separately), not a decode
                // stall, so it must not pollute the stall metric
                let mut last: Option<std::time::Instant> = None;
                let mut max_gap_ms = 0.0f64;
                let mut stats = None;
                for ev in rx {
                    match ev {
                        Event::Token(_) => {
                            if let Some(l) = last {
                                max_gap_ms = max_gap_ms.max(l.elapsed().as_secs_f64() * 1e3);
                            }
                            last = Some(std::time::Instant::now());
                        }
                        Event::Done(s) => {
                            stats = Some(s);
                            break;
                        }
                        Event::Error(e) => panic!("short request failed: {e}"),
                        Event::Cancelled(k) => {
                            panic!("short request cancelled: {}", k.as_str())
                        }
                        Event::Shed => panic!("short request shed with no watermark"),
                    }
                }
                (stats.expect("short ended without Done"), max_gap_ms)
            }));
        }
        // let the shorts reach steady-state decode, then drop the long
        // prompt into the stream
        std::thread::sleep(std::time::Duration::from_millis(if smoke { 30 } else { 100 }));
        let (_, long_stats) = handle
            .generate(Request {
                id: 99,
                prompt: prompt_text(long_prompt_tokens, 99),
                max_new_tokens: 8,
                policy: "lychee".into(),
                deadline_ms: None,
                carried_tokens: 0,
            })
            .unwrap();

        let mut short_ttft = Vec::new();
        let mut short_tpot = Vec::new();
        let mut max_gap: f64 = 0.0;
        for t in short_threads {
            let (s, gap) = t.join().unwrap();
            short_ttft.push(s.ttft_ms);
            short_tpot.push(s.tpot_ms);
            max_gap = max_gap.max(gap);
        }
        let (chunks, preempts) = {
            let m = metrics.lock().unwrap();
            (m.prefill_chunks_executed, m.preemptions)
        };
        handle.shutdown();
        let _ = join.join();

        println!(
            "serving[{mode:<10}] short TPOT p50 {:.2} ms p99 {:.2} ms | worst stall {:.1} ms | long TTFT {:.0} ms",
            percentile(&short_tpot, 0.50),
            percentile(&short_tpot, 0.99),
            max_gap,
            long_stats.ttft_ms
        );
        mode_rows.push(format!(
            "{{\"mode\": \"{mode}\", \"prefill_chunk_tokens\": {chunk}, \
             \"long_prompt_tokens\": {long_prompt_tokens}, \
             \"short_prompt_tokens\": {short_prompt_tokens}, \
             \"short_max_new\": {short_max_new}, \
             \"short_ttft_ms\": {{\"p50\": {:.2}, \"p99\": {:.2}}}, \
             \"short_tpot_ms\": {{\"p50\": {:.3}, \"p99\": {:.3}}}, \
             \"short_max_intertoken_gap_ms\": {:.2}, \
             \"long_ttft_ms\": {:.2}, \"long_tpot_ms\": {:.3}, \
             \"prefill_chunks_executed\": {chunks}, \"preemptions\": {preempts}}}",
            percentile(&short_ttft, 0.50),
            percentile(&short_ttft, 0.99),
            percentile(&short_tpot, 0.50),
            percentile(&short_tpot, 0.99),
            max_gap,
            long_stats.ttft_ms,
            long_stats.tpot_ms,
        ));
    }
    let prefix_fragment = prefix_reuse_fragment();
    let cluster_fragment = cluster_json_fragment();
    let concurrency_fragment = concurrency_json_fragment();
    format!(
        "{{\n  \"schema\": \"lychee-bench-serving-v4\",\n  \"smoke\": {},\n  \
         \"engine\": \"sim\",\n  \"prefill_us_per_token\": {},\n  \"modes\": [\n    {}\n  ],\n  \
         \"prefix_reuse\": {},\n  \"cluster\": {},\n  \"concurrency\": {}\n}}\n",
        smoke,
        prefill_us_per_token,
        mode_rows.join(",\n    "),
        prefix_fragment,
        cluster_fragment,
        concurrency_fragment
    )
}

/// The event-driven-front trajectory (EXPERIMENTS.md §Concurrency):
/// N simultaneous client streams against the epoll reactor, swept over
/// stream counts — client-observed TTFT/TPOT p50+p99, the worst
/// inter-token stall any stream saw, RSS growth per connection, and the
/// peak process thread count during the run (the reactor's headline
/// property: flat where thread-per-connection grows by ~2·N). The
/// smallest size is also replayed against the legacy threads front for
/// a like-for-like comparison.
#[cfg(unix)]
fn concurrency_json_fragment() -> String {
    use lychee::config::Frontend;
    use lychee::coordinator::spawn_with;
    use lychee::engine::sim::{SimConfig, SimEngine};
    use lychee::server::net::sys::raise_nofile_limit;
    use lychee::server::{mux, Server};
    use lychee::util::stats::percentile;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    fn proc_status_kib(key: &str) -> u64 {
        std::fs::read_to_string("/proc/self/status")
            .ok()
            .and_then(|s| {
                s.lines().find_map(|l| {
                    l.strip_prefix(key)
                        .and_then(|v| v.trim().trim_end_matches("kB").trim().parse().ok())
                })
            })
            .unwrap_or(0)
    }
    fn rss_kib() -> u64 {
        proc_status_kib("VmRSS:")
    }
    fn thread_count() -> u64 {
        proc_status_kib("Threads:")
    }

    let smoke = smoke();
    let sizes: &[usize] = if smoke { &[64, 256] } else { &[256, 1024, 4096] };
    let max_new = 4usize;
    let decode_us_per_step = 200u64;

    // fd budget: each stream costs two in-process fds (client end +
    // server end) plus headroom for the poller, listener, and stdio
    let biggest = *sizes.iter().max().unwrap();
    let limit = raise_nofile_limit((4 * biggest + 128) as u64).unwrap_or(1024);
    let cap = ((limit.saturating_sub(128)) / 4) as usize;

    let run_load = |frontend: Frontend, n: usize| -> String {
        let mut cfg = Config::new();
        cfg.serving.frontend = frontend;
        cfg.serving.max_batch = n.max(8);
        cfg.serving.queue_cap = 2 * n + 16;
        let serving = cfg.serving.clone();
        let sim = SimConfig { decode_us_per_step, ..SimConfig::default() };
        let engine_cfg = cfg.clone();
        let (handle, metrics, join) =
            spawn_with(cfg, move || Ok(SimEngine::new(engine_cfg, sim))).unwrap();
        let server = Server::start_single_with(
            "127.0.0.1:0",
            handle.clone(),
            Some(Arc::clone(&metrics)),
            &serving,
        )
        .unwrap();

        let rss_before = rss_kib();
        // sample the thread count while streams are live: the threads
        // front's per-connection threads exit with their sockets, so a
        // post-run reading would hide exactly the growth under test
        let sampling = Arc::new(AtomicBool::new(true));
        let s2 = Arc::clone(&sampling);
        let sampler = std::thread::spawn(move || {
            let mut peak = 0u64;
            while s2.load(Ordering::Relaxed) {
                peak = peak.max(thread_count());
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            peak
        });

        let lines: Vec<String> = (0..n)
            .map(|i| mux::request_line(&format!("concurrent stream {i}"), max_new, "lychee"))
            .collect();
        let t0 = std::time::Instant::now();
        let stats =
            mux::run_streams(&server.addr, &lines, std::time::Duration::from_secs(600)).unwrap();
        let wall_s = t0.elapsed().as_secs_f64();
        let rss_after = rss_kib();
        sampling.store(false, Ordering::Relaxed);
        let peak_threads = sampler.join().unwrap();

        let done = stats.iter().filter(|s| s.outcome == "done").count();
        let ttft: Vec<f64> = stats
            .iter()
            .filter_map(|s| s.ttft.map(|d| d.as_secs_f64() * 1e3))
            .collect();
        // client-observed TPOT: decode span over the non-first tokens
        let tpot: Vec<f64> = stats
            .iter()
            .filter(|s| s.tokens > 1 && s.ttft.is_some())
            .map(|s| {
                let decode = s.total.as_secs_f64() - s.ttft.map(|d| d.as_secs_f64()).unwrap_or(0.0);
                decode * 1e3 / (s.tokens - 1) as f64
            })
            .collect();
        let worst_stall_ms = stats
            .iter()
            .map(|s| s.max_gap.as_secs_f64() * 1e3)
            .fold(0.0f64, f64::max);
        let (wakeups, completed) = {
            let m = metrics.lock().unwrap();
            (m.reactor_wakeups_total, m.completed)
        };
        server.stop();
        handle.shutdown();
        let _ = join.join();

        let rss_delta_kib = rss_after.saturating_sub(rss_before);
        println!(
            "concurrency[{:<7}] {n} streams: {done} done in {wall_s:.2}s | TTFT p99 {:.1} ms | \
             TPOT p99 {:.2} ms | stall {:.1} ms | peak threads {peak_threads} | RSS +{rss_delta_kib} KiB",
            frontend.name(),
            percentile(&ttft, 0.99),
            percentile(&tpot, 0.99),
            worst_stall_ms
        );
        format!(
            "{{\"front\": \"{}\", \"streams\": {n}, \"done\": {done}, \"completed\": {completed}, \
             \"wall_s\": {wall_s:.3}, \
             \"ttft_ms\": {{\"p50\": {:.2}, \"p99\": {:.2}}}, \
             \"tpot_ms\": {{\"p50\": {:.3}, \"p99\": {:.3}}}, \
             \"worst_intertoken_stall_ms\": {:.2}, \
             \"rss_kib_delta\": {rss_delta_kib}, \"rss_bytes_per_conn\": {:.0}, \
             \"peak_threads\": {peak_threads}, \"reactor_wakeups_total\": {wakeups}}}",
            frontend.name(),
            percentile(&ttft, 0.50),
            percentile(&ttft, 0.99),
            percentile(&tpot, 0.50),
            percentile(&tpot, 0.99),
            worst_stall_ms,
            rss_delta_kib as f64 * 1024.0 / n.max(1) as f64,
        )
    };

    let mut rows = Vec::new();
    let mut skipped = Vec::new();
    for &size in sizes {
        if size > cap {
            // no silent caps: sizes the fd limit cannot fund are
            // recorded as skipped, not quietly shrunk
            println!("concurrency: skipping {size} streams (fd limit {limit} allows {cap})");
            skipped.push(size.to_string());
            continue;
        }
        rows.push(run_load(Frontend::Epoll, size));
    }
    let threads_row = if sizes[0] <= cap {
        run_load(Frontend::Threads, sizes[0])
    } else {
        "null".to_string()
    };
    format!(
        "{{\"max_new_tokens\": {max_new}, \"decode_us_per_step\": {decode_us_per_step}, \
         \"nofile_limit\": {limit}, \"skipped_sizes\": [{}], \
         \"reactor\": [{}], \"threads_front\": {}}}",
        skipped.join(", "),
        rows.join(",\n    "),
        threads_row
    )
}

#[cfg(not(unix))]
fn concurrency_json_fragment() -> String {
    "null".to_string()
}

/// The sharded-tier trajectory (EXPERIMENTS.md §Cluster): a session-
/// chained workload swept over 1/2/4 shards — TTFT/TPOT p50+p99,
/// throughput, and the radix hit-rate (consistent-hash routing should
/// keep sessions shard-local, so the hit-rate must not degrade as the
/// shard count grows) — plus a seeded shard-kill run on 2 shards
/// reporting the worst client-visible stall (detection + re-route +
/// recompute: the failover recovery latency) and the failover count.
fn cluster_json_fragment() -> String {
    use lychee::coordinator::cluster::{spawn_cluster_with, Cluster};
    use lychee::coordinator::Request;
    use lychee::engine::sim::{SimConfig, SimEngine};
    use lychee::util::stats::percentile;
    use std::collections::HashMap;

    let smoke = smoke();
    let sessions: usize = if smoke { 8 } else { 24 };
    let turns: usize = if smoke { 2 } else { 3 };
    let turn_tokens: usize = 192;
    let max_new: usize = if smoke { 8 } else { 16 };
    let prefill_us_per_token: u64 = if smoke { 5 } else { 20 };

    let mk_cluster = |shards: usize| -> Cluster {
        let mut cfg = Config::new();
        cfg.serving.shards = shards;
        cfg.serving.prefill_chunk_tokens = 256;
        cfg.serving.max_batch = 8;
        cfg.serving.max_new_tokens = 64;
        cfg.serving.queue_cap = 4096;
        cfg.kv.prefix_cache_mb = 64;
        spawn_cluster_with(cfg, move |_shard, engine_cfg| {
            Ok(SimEngine::new(
                engine_cfg,
                SimConfig { prefill_us_per_token, ..SimConfig::default() },
            ))
        })
        .unwrap()
    };
    let req = |id: u64, prompt: Vec<u8>, max_new: usize| Request {
        id,
        prompt,
        max_new_tokens: max_new,
        policy: "lychee".into(),
        deadline_ms: None,
        carried_tokens: 0,
    };

    // --- shard sweep: the same session-chained load at 1/2/4 shards ----
    let mut sweep_rows = Vec::new();
    for shards in [1usize, 2, 4] {
        let cluster = mk_cluster(shards, None);
        let mut acc: HashMap<usize, Vec<u8>> = HashMap::new();
        let mut ttft = Vec::new();
        let mut tpot = Vec::new();
        let mut next_id = 0u64;
        let t0 = std::time::Instant::now();
        for round in 0..turns {
            let mut workers = Vec::new();
            for s in 0..sessions {
                let mut prompt = acc.remove(&s).unwrap_or_default();
                prompt.extend_from_slice(&prompt_text(
                    turn_tokens,
                    (s * 100 + round) as u64,
                ));
                let c = cluster.clone();
                let r = req(next_id, prompt.clone(), max_new);
                next_id += 1;
                workers.push(std::thread::spawn(move || {
                    let (out, stats) = c.generate(r).expect("cluster sweep request failed");
                    let mut next = prompt;
                    next.extend_from_slice(&out);
                    (s, stats, next)
                }));
            }
            for w in workers {
                let (s, stats, next) = w.join().unwrap();
                ttft.push(stats.ttft_ms);
                tpot.push(stats.tpot_ms);
                acc.insert(s, next);
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let agg = cluster.aggregate_metrics();
        let hit_rate = agg.prefix_hits as f64 / agg.completed.max(1) as f64;
        println!(
            "cluster[{shards} shard] {} reqs in {elapsed:.2}s | ttft p50 {:.1} ms | \
             tpot p50 {:.2} ms | radix hit-rate {hit_rate:.2}",
            sessions * turns,
            percentile(&ttft, 0.50),
            percentile(&tpot, 0.50),
        );
        sweep_rows.push(format!(
            "{{\"shards\": {shards}, \"sessions\": {sessions}, \"turns\": {turns}, \
             \"ttft_ms\": {{\"p50\": {:.2}, \"p99\": {:.2}}}, \
             \"tpot_ms\": {{\"p50\": {:.3}, \"p99\": {:.3}}}, \
             \"throughput_tok_s\": {:.1}, \"prefix_hit_rate\": {hit_rate:.4}}}",
            percentile(&ttft, 0.50),
            percentile(&ttft, 0.99),
            percentile(&tpot, 0.50),
            percentile(&tpot, 0.99),
            agg.tokens_out as f64 / elapsed.max(1e-9),
        ));
        cluster.drain();
        cluster.join();
    }

    format!(
        "{{\n    \"shard_sweep\": [\n      {}\n    ],\n    \"failover\": {}\n  }}",
        sweep_rows.join(",\n      "),
        failover_json_row()
    )
}

/// Failover recovery bench: a seeded shard kill on a 2-shard cluster
/// mid-decode. The kill site only compiles under the `failpoints`
/// feature (`cargo bench --features failpoints`); plain builds emit
/// `null` for this section.
#[cfg(not(feature = "failpoints"))]
fn failover_json_row() -> String {
    "null".to_string()
}

#[cfg(feature = "failpoints")]
fn failover_json_row() -> String {
    use lychee::coordinator::cluster::spawn_cluster_with;
    use lychee::coordinator::{Event, Request};
    use lychee::engine::sim::{SimConfig, SimEngine};
    use lychee::util::fault::{FaultConfig, FaultSpec};

    // The worst inter-token gap any client saw spans the whole recovery:
    // crash detection, re-route, and prompt+streamed-prefix recompute.
    let smoke = smoke();
    let prefill_us_per_token: u64 = if smoke { 5 } else { 20 };
    let n_req = 8u64;
    let fo_max_new: usize = if smoke { 24 } else { 48 };
    let spec = FaultSpec {
        seed: 42,
        cfg: FaultConfig { kill_shard: Some((0, 10)), ..FaultConfig::default() },
    };
    let mut cfg = Config::new();
    cfg.serving.shards = 2;
    cfg.serving.prefill_chunk_tokens = 256;
    cfg.serving.max_batch = 8;
    cfg.serving.max_new_tokens = 64;
    cfg.serving.queue_cap = 4096;
    cfg.kv.prefix_cache_mb = 64;
    let cluster = spawn_cluster_with(cfg, move |_shard, engine_cfg| {
        Ok(SimEngine::new(
            engine_cfg,
            SimConfig {
                prefill_us_per_token,
                faults: Some(spec.clone()),
                ..SimConfig::default()
            },
        ))
    })
    .unwrap();

    let mut workers = Vec::new();
    for i in 0..n_req {
        let rx = cluster
            .submit(Request {
                id: i,
                prompt: prompt_text(320, 9000 + i),
                max_new_tokens: fo_max_new,
                policy: "lychee".into(),
                deadline_ms: None,
                carried_tokens: 0,
            })
            .unwrap();
        workers.push(std::thread::spawn(move || {
            let mut last: Option<std::time::Instant> = None;
            let mut max_gap_ms = 0.0f64;
            let mut tokens = 0usize;
            let mut done = false;
            for ev in rx {
                match ev {
                    Event::Token(_) => {
                        if let Some(l) = last {
                            max_gap_ms = max_gap_ms.max(l.elapsed().as_secs_f64() * 1e3);
                        }
                        last = Some(std::time::Instant::now());
                        tokens += 1;
                    }
                    Event::Done(_) => {
                        done = true;
                        break;
                    }
                    Event::Error(e) => panic!("failover bench request failed: {e}"),
                    Event::Cancelled(k) => {
                        panic!("failover bench request cancelled: {}", k.as_str())
                    }
                    Event::Shed => panic!("failover bench request shed"),
                }
            }
            assert!(done && tokens == fo_max_new, "lost tokens across failover");
            max_gap_ms
        }));
    }
    let mut worst_gap: f64 = 0.0;
    for w in workers {
        worst_gap = worst_gap.max(w.join().unwrap());
    }
    let snap = cluster.router_snapshot();
    println!(
        "cluster[failover] {} reqs over a shard kill: {} failovers, worst stall {worst_gap:.1} ms",
        n_req, snap.failovers_total
    );
    let row = format!(
        "{{\"shards\": 2, \"requests\": {n_req}, \"max_new\": {fo_max_new}, \
         \"failovers\": {}, \"shard0_alive\": {}, \
         \"recovery_worst_stall_ms\": {worst_gap:.2}}}",
        snap.failovers_total,
        cluster.shard_alive(0)
    );
    cluster.drain();
    cluster.join();
    row
}

/// The shared-prefix radix trajectory: the multiturn workload (shared
/// system prompt + session-chained turns) through the real coordinator
/// over SimEngine, radix-on vs radix-off, at several session counts.
/// Reports first-turn and later-turn ("short-turn") TTFT, prefill chunks
/// executed, radix hit-rate, and the shared-bytes dedup ratio.
fn prefix_reuse_fragment() -> String {
    use lychee::coordinator::{spawn_with, Request};
    use lychee::engine::sim::{SimConfig, SimEngine};
    use lychee::util::stats::percentile;
    use lychee::workloads::multiturn::{generate, MultiTurnParams};
    use std::collections::HashMap;

    let smoke = smoke();
    let session_counts: &[usize] = if smoke { &[8, 32] } else { &[8, 32, 128] };
    let turns = if smoke { 2 } else { 3 };
    let system_prompt_len = if smoke { 512 } else { 1024 };
    let prefill_us_per_token: u64 = if smoke { 5 } else { 20 };

    let mut rows = Vec::new();
    for &sessions in session_counts {
        for radix_on in [true, false] {
            let mut cfg = Config::new();
            cfg.kv.prefix_cache_mb = if radix_on { 256 } else { 0 };
            cfg.serving.prefill_chunk_tokens = 256;
            cfg.serving.max_batch = 16;
            cfg.serving.max_new_tokens = 64;
            cfg.serving.queue_cap = 4096;
            let sim = SimConfig { prefill_us_per_token, ..SimConfig::default() };
            let engine_cfg = cfg.clone();
            let (handle, metrics, join) =
                spawn_with(cfg, move || Ok(SimEngine::new(engine_cfg, sim))).unwrap();

            let p = MultiTurnParams {
                sessions,
                turns,
                branch: 1,
                system_prompt_len,
                turn_len_min: 96,
                turn_len_max: 160,
                reply_tokens: 8,
            };
            let plan = generate(&p, 7);
            // drive round-by-round: all paths' turn t in parallel, then
            // chain each path's accumulated text (prompt + real reply)
            let mut acc: HashMap<String, Vec<u8>> = HashMap::new();
            let mut first_ttft = Vec::new();
            let mut later_ttft = Vec::new();
            let mut total_bytes_touched = 0usize;
            for round in 0..turns {
                let round_turns: Vec<_> =
                    plan.iter().filter(|t| t.turn == round).cloned().collect();
                let mut workers = Vec::new();
                for t in round_turns {
                    let base = match &t.fork_of {
                        Some(trunk) => acc.get(trunk).cloned().unwrap_or_default(),
                        None => acc.get(&t.session).cloned().unwrap_or_default(),
                    };
                    let mut prompt = base;
                    prompt.extend_from_slice(&t.text);
                    let h = handle.clone();
                    workers.push(std::thread::spawn(move || {
                        let (out, stats) = h
                            .generate(Request {
                                id: 0,
                                prompt: prompt.clone(),
                                max_new_tokens: t.max_new_tokens,
                                policy: "lychee".into(),
                                deadline_ms: None,
                                carried_tokens: 0,
                            })
                            .expect("multiturn request failed");
                        let mut next = prompt;
                        next.extend_from_slice(&out);
                        (t.session, t.turn, stats.ttft_ms, next)
                    }));
                }
                for w in workers {
                    let (session, turn, ttft, next) = w.join().unwrap();
                    total_bytes_touched += lychee::kvcache::KvCache::estimate_bytes(
                        2,
                        2,
                        8,
                        next.len(),
                    );
                    if turn == 0 {
                        first_ttft.push(ttft);
                    } else {
                        later_ttft.push(ttft);
                    }
                    acc.insert(session, next);
                }
            }
            let (chunks, hits, reqs, shared, evictions) = {
                let m = metrics.lock().unwrap();
                (
                    m.prefill_chunks_executed,
                    m.prefix_hits,
                    m.completed.max(1),
                    m.kv_bytes_shared,
                    m.prefix_evictions,
                )
            };
            handle.shutdown();
            let _ = join.join();
            let hit_rate = hits as f64 / reqs as f64;
            let shared_ratio = shared as f64 / (total_bytes_touched.max(1) as f64);
            println!(
                "prefix_reuse[{:>3} sessions, radix {:>3}] later-turn TTFT p50 {:>7.1} ms \
                 p99 {:>7.1} ms | chunks {chunks:>5} | hit-rate {hit_rate:.2} | shared-ratio {shared_ratio:.3}",
                sessions,
                if radix_on { "on" } else { "off" },
                percentile(&later_ttft, 0.50),
                percentile(&later_ttft, 0.99),
            );
            rows.push(format!(
                "{{\"sessions\": {sessions}, \"radix\": {radix_on}, \"turns\": {turns}, \
                 \"system_prompt_len\": {system_prompt_len}, \
                 \"first_turn_ttft_ms\": {{\"p50\": {:.2}, \"p99\": {:.2}}}, \
                 \"later_turn_ttft_ms\": {{\"p50\": {:.2}, \"p99\": {:.2}}}, \
                 \"prefill_chunks_executed\": {chunks}, \"prefix_hit_rate\": {hit_rate:.4}, \
                 \"kv_bytes_shared\": {shared}, \"shared_bytes_ratio\": {shared_ratio:.4}, \
                 \"prefix_evictions\": {evictions}}}",
                percentile(&first_ttft, 0.50),
                percentile(&first_ttft, 0.99),
                percentile(&later_ttft, 0.50),
                percentile(&later_ttft, 0.99),
            ));
        }
    }
    format!(
        "{{\n    \"prefill_us_per_token\": {prefill_us_per_token},\n    \"runs\": [\n      {}\n    ]\n  }}",
        rows.join(",\n      ")
    )
}

/// The mixed-precision sweep (EXPERIMENTS.md §Precision): per-policy
/// select+gather latency and gather bytes-moved per decode token at each
/// storage precision (`kv.precision` pages + `index.rep_precision`
/// mirrors), plus arena capacity (max resident sequences) at the default
/// `serving.kv_pool_mb`. `BENCH_PRECISION=f16` (etc.) narrows the sweep
/// to one precision per CI matrix leg — f32 always runs as the baseline.
fn precision_json_fragment() -> String {
    use lychee::engine::LayerKeys;
    use lychee::kvcache::{KvCache as Cache, PagePool};
    use lychee::quant::Precision;
    use lychee::sparse::Policy;
    use std::sync::Arc;

    let smoke = smoke();
    let d = 64usize;
    let contexts: &[usize] = if smoke { &[4 * 1024] } else { &[4 * 1024, 16 * 1024, 32 * 1024] };
    let (warm, iters) = if smoke { (1, 5) } else { (2, 30) };
    let policies = ["lychee", "quest", "clusterkv", "arkvale", "shadowkv"];
    let mut precisions: Vec<Precision> = vec![Precision::F32];
    match std::env::var("BENCH_PRECISION").ok().as_deref().and_then(Precision::parse) {
        Some(Precision::F32) | None => {
            precisions.push(Precision::F16);
            precisions.push(Precision::I8);
        }
        Some(p) => precisions.push(p),
    }

    let mut sweep_rows = Vec::new();
    // (precision, context, policy) -> combined µs, for the speedup rows
    let mut combined: std::collections::BTreeMap<(String, usize, String), f64> =
        std::collections::BTreeMap::new();
    for &prec in &precisions {
        for &n in contexts {
            let mut rng = Rng::new(0x9EC1 ^ n as u64);
            let mut cache =
                Cache::with_pool_precision(1, 1, d, PagePool::unbounded(), prec);
            for _ in 0..n {
                let kr = rng.normal_vec(d);
                cache.append_token(&[&kr], &[&kr]).unwrap();
            }
            let text = prompt_text(n, 2);
            let mut cfg = LycheeConfig::default();
            cfg.rep_precision = prec;
            let m = 1024usize; // budget bucket for the gather buffers
            let mut kb = vec![0.0f32; m * d];
            let mut vb = vec![0.0f32; m * d];
            let mut mb = vec![0.0f32; m];
            for name in policies {
                let mut p = make_policy(name, &cfg, 1, 4).unwrap();
                {
                    let keys = LayerKeys { cache: &cache, layer: 0, n };
                    p.build(&Ctx { keys: &keys, text: &text, n });
                }
                let q = rng.normal_vec(d);
                let mut scratch = SelectScratch::new();
                let sel = {
                    let keys = LayerKeys { cache: &cache, layer: 0, n };
                    let ctx = Ctx { keys: &keys, text: &text, n };
                    p.select_into(&ctx, &q, n, &mut scratch);
                    std::mem::take(&mut scratch.out)
                };
                let select = bench_quiet(warm, iters, || {
                    let keys = LayerKeys { cache: &cache, layer: 0, n };
                    let ctx = Ctx { keys: &keys, text: &text, n };
                    p.select_into(&ctx, &q, n, &mut scratch);
                    std::hint::black_box(&scratch.out);
                });
                let gather = bench_quiet(warm, iters, || {
                    cache.gather_into(0, &sel, &mut kb, &mut vb, &mut mb);
                    std::hint::black_box(&kb);
                });
                let comb = select.mean + gather.mean;
                // K+V code/element bytes streamed per decode token-step
                let gather_gb = (2 * sel.len() * d * prec.bytes_per_elem()) as f64 / 1e9;
                println!(
                    "precision[{:>3}] {name:<10} @{:>2}k  select {:>8.1} µs  gather {:>8.1} µs  ({:.3} MB/tok)",
                    prec.name(),
                    n / 1024,
                    select.mean,
                    gather.mean,
                    gather_gb * 1e3
                );
                combined.insert((prec.name().to_string(), n, name.to_string()), comb);
                sweep_rows.push(format!(
                    "{{\"precision\": \"{}\", \"context_tokens\": {n}, \"policy\": \"{name}\", \
                     \"select_us\": {:.2}, \"gather_us\": {:.2}, \"combined_us\": {:.2}, \
                     \"ns_per_ctx_token\": {:.3}, \"gather_gb_per_token\": {:.6}}}",
                    prec.name(),
                    select.mean,
                    gather.mean,
                    comb,
                    comb * 1000.0 / n as f64,
                    gather_gb
                ));
            }
        }
    }

    // arena capacity at a fixed pool: how many 32k-token sequences fit a
    // default-sized arena at each precision (serving-geometry estimate)
    let pool_mb = lychee::config::ServingConfig::default().kv_pool_mb;
    let pool_bytes = pool_mb * 1024 * 1024;
    let seq_tokens = 32 * 1024;
    let f32_est = Cache::estimate_bytes_at(8, 8, 64, seq_tokens, Precision::F32);
    let mut arena_rows = Vec::new();
    for &prec in &precisions {
        let est = Cache::estimate_bytes_at(8, 8, 64, seq_tokens, prec);
        arena_rows.push(format!(
            "{{\"precision\": \"{}\", \"seq_tokens\": {seq_tokens}, \
             \"bytes_per_seq\": {est}, \"max_resident_seqs\": {}, \
             \"capacity_ratio_vs_f32\": {:.3}}}",
            prec.name(),
            pool_bytes / est.max(1),
            f32_est as f64 / est.max(1) as f64
        ));
    }

    // headline: combined select+gather speedup vs f32 at the largest
    // measured context, averaged over the policy roster
    let top_ctx = *contexts.last().unwrap();
    let mut speedup_rows = Vec::new();
    for &prec in &precisions {
        if prec == Precision::F32 {
            continue;
        }
        let mut ratios = Vec::new();
        for name in policies {
            let base = combined.get(&("f32".to_string(), top_ctx, name.to_string()));
            let ours = combined.get(&(prec.name().to_string(), top_ctx, name.to_string()));
            if let (Some(&b), Some(&o)) = (base, ours) {
                if o > 0.0 {
                    ratios.push(b / o);
                }
            }
        }
        let mean = if ratios.is_empty() {
            0.0
        } else {
            ratios.iter().sum::<f64>() / ratios.len() as f64
        };
        println!(
            "precision[{:>3}] combined select+gather speedup vs f32 @{}k: {mean:.2}x",
            prec.name(),
            top_ctx / 1024
        );
        speedup_rows.push(format!(
            "{{\"precision\": \"{}\", \"context_tokens\": {top_ctx}, \"speedup\": {mean:.3}}}",
            prec.name()
        ));
    }

    format!(
        "{{\n    \"kv_pool_mb\": {pool_mb},\n    \"sweep\": [\n      {}\n    ],\n    \
         \"arena\": [\n      {}\n    ],\n    \"combined_speedup\": [\n      {}\n    ]\n  }}",
        sweep_rows.join(",\n      "),
        arena_rows.join(",\n      "),
        speedup_rows.join(",\n      ")
    )
}

/// Log-log least-squares slope: the fitted exponent `b` in
/// `select_us ≈ a · rows^b`.
fn fit_exponent(rows: &[f64], us: &[f64]) -> f64 {
    if rows.len() < 2 {
        return 0.0;
    }
    let n = rows.len() as f64;
    let xs: Vec<f64> = rows.iter().map(|r| r.ln()).collect();
    let ys: Vec<f64> = us.iter().map(|t| t.max(1e-3).ln()).collect();
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let num: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Dense vs block-max select at growing context lengths (32k → 1M
/// tokens; `BENCH_SMOKE=1` stops at 128k): per-path select µs, the
/// fraction of 64-row blocks actually scanned, a byte-identity spot
/// check, and the fitted growth exponent per backend (the acceptance
/// gate wants blockmax sub-linear — exponent < 1 with a falling
/// scanned fraction — while dense stays ~linear). Indexes are built
/// from topic-structured representatives (M = tokens/48 rows, ~256
/// contiguous rows per topic) so block bounds see realistic score skew;
/// `BENCH_PRECISION` selects the mirror precision (default f32).
fn blockmax_json_fragment() -> String {
    use lychee::index::ScoringBackend;
    use lychee::quant::Precision;
    use lychee::sparse::{blocks_pruned_total, blocks_scanned_total};

    let smoke = smoke();
    let d = 32usize;
    let span = 48usize;
    let budget = 1024usize;
    let contexts: &[usize] = if smoke {
        &[32 * 1024, 128 * 1024]
    } else {
        &[32 * 1024, 128 * 1024, 512 * 1024, 1024 * 1024]
    };
    let (warm, iters) = if smoke { (1, 3) } else { (2, 20) };
    let prec = std::env::var("BENCH_PRECISION")
        .ok()
        .as_deref()
        .and_then(Precision::parse)
        .unwrap_or(Precision::F32);

    let mut ctx_rows = Vec::new();
    // per path: (rows, dense_us, blockmax_us) series for the exponent fit
    let mut series: Vec<(&str, Vec<(f64, f64, f64)>)> =
        vec![("flat", Vec::new()), ("hier", Vec::new())];
    for &n in contexts {
        let rows = n / span;
        let mut rng = Rng::new(0xB10C ^ n as u64);
        let topics = (rows / 256).max(4);
        let dirs: Vec<Vec<f32>> = (0..topics).map(|_| rng.unit_vec(d)).collect();
        let mut reps = Vec::with_capacity(rows * d);
        for r in 0..rows {
            let dir = &dirs[(r / 256) % topics];
            for &dj in dir.iter() {
                reps.push(dj + 0.15 * rng.normal());
            }
        }
        let spans: Vec<Chunk> =
            (0..rows).map(|i| Chunk { start: i * span, len: span }).collect();
        let mut params = IndexParams::default();
        params.rep_precision = prec;
        // build cost is not the measurand here; fewer k-means iterations
        // keep the 512k/1M builds tractable without touching select
        params.kmeans_iters = 4;
        let dense = HierarchicalIndex::build_from_reps(d, params.clone(), &spans, reps.clone());
        params.scoring_backend = ScoringBackend::Blockmax;
        let mut bm = HierarchicalIndex::build_from_reps(d, params, &spans, reps);
        bm.ensure_blockmax();

        // topic-leaning query: realistic skew (a fully random query still
        // pins identity but exercises little pruning)
        let mut q = dirs[topics / 2].clone();
        for x in q.iter_mut() {
            *x += 0.25 * rng.normal();
        }

        for (pi, (path, kgkc)) in
            [("flat", None), ("hier", Some((8usize, 64usize)))].into_iter().enumerate()
        {
            let mut scratch = SelectScratch::new();
            let dn = bench(
                &format!("{path} dense    select @{}k", n / 1024),
                warm,
                iters,
                || {
                    match kgkc {
                        Some((kg, kc)) => dense.select_tokens_into(&q, kg, kc, budget, &mut scratch),
                        None => dense.select_tokens_flat_into(&q, budget, &mut scratch),
                    }
                    std::hint::black_box(&scratch.tokens);
                },
            );
            // byte-identity spot check before the counter window
            let same = match kgkc {
                Some((kg, kc)) => {
                    dense.select_tokens(&q, kg, kc, budget) == bm.select_tokens(&q, kg, kc, budget)
                }
                None => dense.select_tokens_flat(&q, budget) == bm.select_tokens_flat(&q, budget),
            };
            if !same {
                println!("WARNING: blockmax selection diverged from dense ({path} @{n})");
            }
            let (s0, p0) = (blocks_scanned_total(), blocks_pruned_total());
            let bn = bench(
                &format!("{path} blockmax select @{}k", n / 1024),
                warm,
                iters,
                || {
                    match kgkc {
                        Some((kg, kc)) => bm.select_tokens_into(&q, kg, kc, budget, &mut scratch),
                        None => bm.select_tokens_flat_into(&q, budget, &mut scratch),
                    }
                    std::hint::black_box(&scratch.tokens);
                },
            );
            let scanned = (blocks_scanned_total() - s0) as f64;
            let pruned = (blocks_pruned_total() - p0) as f64;
            let frac =
                if scanned + pruned > 0.0 { scanned / (scanned + pruned) } else { 1.0 };
            println!(
                "blockmax[{path}] @{}k rows={rows}: {:.2}x vs dense, {:.0}% blocks scanned",
                n / 1024,
                if bn.mean > 0.0 { dn.mean / bn.mean } else { 0.0 },
                frac * 100.0
            );
            ctx_rows.push(format!(
                "{{\"context_tokens\": {n}, \"rows\": {rows}, \"path\": \"{path}\", \
                 \"dense_us\": {:.2}, \"blockmax_us\": {:.2}, \
                 \"blocks_scanned_frac\": {frac:.4}, \"identical\": {same}}}",
                dn.mean, bn.mean
            ));
            series[pi].1.push((rows as f64, dn.mean, bn.mean));
        }
    }

    let mut exp_rows = Vec::new();
    for (path, pts) in &series {
        let rs: Vec<f64> = pts.iter().map(|p| p.0).collect();
        let du: Vec<f64> = pts.iter().map(|p| p.1).collect();
        let bu: Vec<f64> = pts.iter().map(|p| p.2).collect();
        let de = fit_exponent(&rs, &du);
        let be = fit_exponent(&rs, &bu);
        println!("blockmax[{path}] growth exponent: dense {de:.2}, blockmax {be:.2}");
        exp_rows.push(format!(
            "{{\"path\": \"{path}\", \"dense\": {de:.3}, \"blockmax\": {be:.3}}}"
        ));
    }

    format!(
        "{{\"precision\": \"{}\", \"budget\": {budget}, \"span\": {span}, \
         \"contexts\": [\n    {}\n  ], \"growth_exponent\": [\n    {}\n  ]}}",
        prec.name(),
        ctx_rows.join(",\n    "),
        exp_rows.join(",\n    ")
    )
}

/// The perf-trajectory section: measures the scoring/select hot path and
/// renders `BENCH_retrieval.json` (schema documented in EXPERIMENTS.md
/// §Perf). Returns the JSON text.
fn retrieval_json_section() -> String {
    let d = 32usize;
    let smoke = smoke();
    let contexts: &[usize] = if smoke { &[4 * 1024] } else { &[4 * 1024, 16 * 1024, 32 * 1024] };
    let (warm, iters) = if smoke { (1, 5) } else { (3, 50) };
    let policies = ["lychee", "quest", "clusterkv", "arkvale", "shadowkv"];
    let cfg = LycheeConfig::default();

    // --- per-policy select latency at several context lengths ----------
    let mut select_rows = Vec::new();
    for &n in contexts {
        let mut rng = Rng::new(0xBE9C4 ^ n as u64);
        let text = prompt_text(n, 1);
        let keys: Vec<f32> = rng.normal_vec(n * d);
        let src = FlatKeys::new(&keys, d);
        for name in policies {
            let mut p = make_policy(name, &cfg, 1, 4).unwrap();
            let ctx = Ctx { keys: &src, text: &text, n };
            p.build(&ctx);
            let q = rng.normal_vec(d);
            let mut scratch = SelectScratch::new();
            let s = bench(
                &format!("{name} select_into @{}k", n / 1024),
                warm,
                iters,
                || {
                    p.select_into(&ctx, &q, n, &mut scratch);
                    std::hint::black_box(&scratch.out);
                },
            );
            select_rows.push(format!(
                "{{\"context_tokens\": {n}, \"policy\": \"{name}\", \
                 \"select_us_mean\": {:.2}, \"ns_per_ctx_token\": {:.3}}}",
                s.mean,
                s.mean * 1000.0 / n as f64
            ));
        }
    }

    // --- SoA+SIMD scoring vs the seed-style scalar path at 32k ---------
    // Seed layout: one separately-allocated Vec per chunk rep, scored
    // with per-row scalar dot (pointer chasing + no GEMV blocking).
    // Current layout: one contiguous [rows, d] matrix + blocked GEMV.
    let score_d = 64usize;
    let rows = 32 * 1024 / 48; // chunk reps of a 32k-token context
    let mut rng = Rng::new(0x5C0FE);
    let flat: Vec<f32> = rng.normal_vec(rows * score_d);
    let nested: Vec<Vec<f32>> = (0..rows)
        .map(|r| flat[r * score_d..(r + 1) * score_d].to_vec())
        .collect();
    let q = rng.normal_vec(score_d);
    let mut out = vec![0.0f32; rows];
    let (sw, si) = if smoke { (2, 20) } else { (10, 300) };
    let scalar = bench(&format!("score {rows}x{score_d} scalar AoS (seed path)"), sw, si, || {
        for (o, row) in out.iter_mut().zip(&nested) {
            *o = linalg::simd::scalar_dot(row, &q);
        }
        std::hint::black_box(&out);
    });
    let simd = bench(&format!("score {rows}x{score_d} SIMD SoA (matvec)"), sw, si, || {
        linalg::matvec(&flat, score_d, &q, &mut out);
        std::hint::black_box(&out);
    });
    let speedup = if simd.mean > 0.0 { scalar.mean / simd.mean } else { 0.0 };
    println!("score path speedup (scalar AoS -> SIMD SoA): {speedup:.2}x");

    // --- serial vs parallel batch retrieval (select + gather) ----------
    use lychee::engine::LayerKeys;
    use lychee::kvcache::PagePool;
    use lychee::sparse::Policy;
    use lychee::util::threadpool::scoped_map_mut;
    use std::sync::Arc;

    let bd = 64usize;
    let ctx_tokens = if smoke { 2 * 1024 } else { 8 * 1024 };
    let pool = PagePool::unbounded();
    struct BatchSeq {
        kv: KvCache,
        policy: Box<dyn Policy>,
        text: Vec<u8>,
        q: Vec<f32>,
        scratch: SelectScratch,
    }
    let mk_seq = |i: usize| -> BatchSeq {
        let mut rng = Rng::new(0xBA7C4 + i as u64);
        let mut kv = KvCache::with_pool(1, 1, bd, Arc::clone(&pool));
        let text = prompt_text(ctx_tokens, i as u64);
        for _ in 0..ctx_tokens {
            let kr = rng.normal_vec(bd);
            kv.append_token(&[&kr], &[&kr]).unwrap();
        }
        let mut policy = make_policy("lychee", &cfg, 1, 4).unwrap();
        {
            let keys = LayerKeys { cache: &kv, layer: 0, n: ctx_tokens };
            policy.build(&Ctx { keys: &keys, text: &text, n: ctx_tokens });
        }
        BatchSeq { kv, policy, text, q: rng.normal_vec(bd), scratch: SelectScratch::new() }
    };
    let m = 2048usize;
    let (bw, bi) = if smoke { (1, 3) } else { (2, 15) };
    let mut batch_rows = Vec::new();
    for bsz in [1usize, 2, 4, 8] {
        let mut batch: Vec<BatchSeq> = (0..bsz).map(mk_seq).collect();
        let mut kb = vec![0.0f32; bsz * m * bd];
        let mut vb = vec![0.0f32; bsz * m * bd];
        let mut mb = vec![0.0f32; bsz * m];
        let serial = bench(&format!("json serial   select+gather b={bsz}"), bw, bi, || {
            for i in 0..bsz {
                let sel = {
                    let s = &mut batch[i];
                    let keys = LayerKeys { cache: &s.kv, layer: 0, n: ctx_tokens };
                    let ctx = Ctx { keys: &keys, text: &s.text, n: ctx_tokens };
                    s.policy.select_into(&ctx, &s.q, ctx_tokens, &mut s.scratch);
                    std::mem::take(&mut s.scratch.out)
                };
                batch[i].kv.gather_into(
                    0,
                    &sel,
                    &mut kb[i * m * bd..(i + 1) * m * bd],
                    &mut vb[i * m * bd..(i + 1) * m * bd],
                    &mut mb[i * m..(i + 1) * m],
                );
                batch[i].scratch.out = sel;
            }
            std::hint::black_box(&kb);
        });
        let parallel = bench(&format!("json parallel select+gather b={bsz}"), bw, bi, || {
            let sels: Vec<Vec<usize>> = scoped_map_mut(&mut batch, bsz, |_i, s| {
                let keys = LayerKeys { cache: &s.kv, layer: 0, n: ctx_tokens };
                let ctx = Ctx { keys: &keys, text: &s.text, n: ctx_tokens };
                s.policy.select_into(&ctx, &s.q, ctx_tokens, &mut s.scratch);
                std::mem::take(&mut s.scratch.out)
            });
            let caches: Vec<&KvCache> = batch.iter().map(|s| &s.kv).collect();
            lychee::kvcache::gather_batch_into(
                &caches, 0, &sels, m, &mut kb, &mut vb, &mut mb, bsz,
            );
            for (s, sel) in batch.iter_mut().zip(sels) {
                s.scratch.out = sel;
            }
            std::hint::black_box(&kb);
        });
        batch_rows.push(format!(
            "{{\"batch\": {bsz}, \"context_tokens\": {ctx_tokens}, \
             \"serial_us\": {:.1}, \"parallel_us\": {:.1}}}",
            serial.mean, parallel.mean
        ));
    }

    // --- mixed-precision sweep (pages + rep mirrors) -------------------
    let precision_fragment = precision_json_fragment();

    // --- dense vs block-max select, 32k -> 1M --------------------------
    let blockmax_fragment = blockmax_json_fragment();

    format!(
        "{{\n  \"schema\": \"lychee-bench-retrieval-v3\",\n  \
         \"backend\": \"{}\",\n  \"f16c\": {},\n  \"smoke\": {},\n  \"select_dim\": {},\n  \
         \"select\": [\n    {}\n  ],\n  \
         \"score_32k\": {{\"rows\": {rows}, \"d\": {score_d}, \
         \"scalar_aos_us\": {:.2}, \"simd_soa_us\": {:.2}, \"speedup\": {:.2}}},\n  \
         \"batch\": [\n    {}\n  ],\n  \
         \"precision\": {},\n  \
         \"blockmax\": {}\n}}\n",
        linalg::simd::backend().name(),
        linalg::simd::f16c_available(),
        smoke,
        d,
        select_rows.join(",\n    "),
        scalar.mean,
        simd.mean,
        speedup,
        batch_rows.join(",\n    "),
        precision_fragment,
        blockmax_fragment
    )
}
