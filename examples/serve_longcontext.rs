//! End-to-end serving driver (the repo's system-level validation run,
//! recorded in EXPERIMENTS.md):
//!
//! 1. starts the coordinator + TCP JSON-lines server on the real
//!    LycheeLM artifacts,
//! 2. replays a Poisson arrival trace of batched requests through the
//!    TCP client and reports TTFT / TPOT / throughput,
//! 3. then measures single-stream decode TPOT at long synthetic contexts
//!    for full attention vs LycheeCluster (the Fig. 4 phenomenon, live).
//!
//! ```bash
//! cargo run --release --offline --example serve_longcontext
//! ```

use lychee::config::Config;
use lychee::coordinator::spawn;
use lychee::engine::{Engine, Sampling};
use lychee::server::{Client, Server};
use lychee::util::stats::mean;
use lychee::workloads::trace::{self, TraceParams};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::new();
    if !std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        cfg.artifacts_dir = "artifacts".into();
    }

    // ---------------------------------------------------------------
    // Phase 1: batched serving over TCP
    // ---------------------------------------------------------------
    println!("=== phase 1: batched serving over TCP (lychee policy) ===");
    let (handle, metrics, join) = spawn(cfg.clone())?;
    let server =
        Server::start("127.0.0.1:0", handle.clone(), Some(std::sync::Arc::clone(&metrics)))?;
    println!("server on {}", server.addr);

    let params = TraceParams { rate: 4.0, n_requests: 12, prompt_min: 96, prompt_max: 480, out_min: 8, out_max: 24 };
    let reqs = trace::generate(&params, 7);
    let t0 = std::time::Instant::now();
    let addr = server.addr;
    let mut workers = Vec::new();
    for (i, r) in reqs.into_iter().enumerate() {
        workers.push(std::thread::spawn(move || -> anyhow::Result<(f64, f64)> {
            let wait = r.at_s - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wait));
            }
            let prompt = String::from_utf8_lossy(&trace::prompt_text(r.prompt_len, i as u64)).into_owned();
            let mut client = Client::connect(&addr)?;
            let res = client.generate(&prompt, r.max_new_tokens, "lychee")?;
            Ok((res.ttft_ms, res.tpot_ms))
        }));
    }
    let mut ttfts = Vec::new();
    let mut tpots = Vec::new();
    for w in workers {
        let (ttft, tpot) = w.join().unwrap()?;
        ttfts.push(ttft);
        tpots.push(tpot);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    {
        let m = metrics.lock().unwrap();
        println!(
            "served {} requests in {:.1}s | throughput {:.1} tok/s | mean TTFT {:.0} ms | mean TPOT {:.2} ms",
            m.completed,
            elapsed,
            m.throughput_tokens_per_s(elapsed),
            mean(&ttfts),
            mean(&tpots)
        );
    }
    server.stop();
    handle.shutdown();
    let _ = join.join();

    // ---------------------------------------------------------------
    // Phase 2: long-context TPOT, full vs lychee (single stream)
    // ---------------------------------------------------------------
    println!("\n=== phase 2: long-context decode TPOT (single stream) ===");
    let engine = Engine::load(cfg)?;
    let sampling = Sampling::default();
    println!("{:<10} {:>12} {:>12} {:>9}", "context", "full ms/tok", "lychee ms/tok", "speedup");
    for ctx in [8 * 1024usize, 16 * 1024, 32 * 1024] {
        let mut times = Vec::new();
        for policy in ["full", "lychee"] {
            let mut seq = engine.synth_sequence(1, ctx, policy, 11)?;
            engine.decode_step(&mut seq, &sampling)?; // warmup
            let mut samples = Vec::new();
            for _ in 0..4 {
                let t = std::time::Instant::now();
                engine.decode_step(&mut seq, &sampling)?;
                samples.push(t.elapsed().as_secs_f64() * 1e3);
            }
            times.push(mean(&samples));
        }
        println!(
            "{:<10} {:>12.2} {:>12.2} {:>8.2}x",
            format!("{}k", ctx / 1024),
            times[0],
            times[1],
            times[0] / times[1]
        );
    }
    Ok(())
}
