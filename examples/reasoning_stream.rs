//! Reasoning-stream demo (the paper's MATH500 scenario): a chain-of-
//! thought generation where each step must recall an earlier premise.
//! Compares LycheeCluster's lazy-updated index against eviction baselines
//! on premise-recall accuracy and prints the stability metrics of
//! Appendix D (Jaccard / window-hit).
//!
//! ```bash
//! cargo run --release --offline --example reasoning_stream
//! ```

use lychee::config::LycheeConfig;
use lychee::eval::runner::run_cot;
use lychee::util::stats::mean;
use lychee::workloads::mathcot;

fn main() {
    let mut cfg = LycheeConfig::default();
    cfg.budget = 512;
    cfg.sink = 16;
    cfg.recent = 64;

    let inst = mathcot::generate(8, 200, 72, 42);
    println!(
        "CoT instance: {} premise tokens + {} steps x 72 tokens = {} total",
        inst.prompt.n_tokens(),
        inst.steps.len(),
        inst.total_tokens()
    );
    println!(
        "{:<12} {:>9} {:>12} {:>12} {:>10} {:>11}",
        "policy", "accuracy", "select µs", "update µs/tok", "jaccard", "window-hit"
    );
    for policy in ["full", "lychee", "quest", "h2o", "raas", "streaming"] {
        let r = run_cot(&inst, policy, &cfg).expect("policy in registry");
        println!(
            "{:<12} {:>8.1}% {:>12.1} {:>12.2} {:>10.3} {:>11.3}",
            policy,
            r.accuracy * 100.0,
            r.select_us_mean,
            r.update_us_mean,
            mean(&r.jaccard_series),
            mean(&r.window_hit_series),
        );
    }
    println!("\n(h2o/raas lose early premises to eviction; lychee grafts new steps lazily and keeps them recallable)");
}
