//! Structured-data retrieval demo — the paper's Figure 1 story, live:
//! a JSON stream is segmented three ways (fixed pages, token clusters,
//! structure-aware chunks), a needle record is queried, and the demo
//! shows which methods return the record *intact*.
//!
//! ```bash
//! cargo run --release --offline --example structured_data
//! ```

use lychee::chunking::{chunk_stats, Chunker, FixedSizeChunker, StructureAwareChunker};
use lychee::config::LycheeConfig;
use lychee::eval::runner::run_task;
use lychee::index::reps::FlatKeys;
use lychee::sparse::{make_policy, Ctx};
use lychee::workloads::structext;

fn main() {
    let task = structext::generate("json", 4096, 8, 3);
    println!("JSON stream: {} bytes, {} records\n", task.n_tokens(), task.units.len());

    // --- segmentation comparison -----------------------------------
    let sa = StructureAwareChunker::default();
    let fx = FixedSizeChunker::new(16);
    let sa_chunks = sa.chunk(&task.text);
    let fx_chunks = fx.chunk(&task.text);
    let sa_stats = chunk_stats(&task.text, &sa_chunks);
    let fx_stats = chunk_stats(&task.text, &fx_chunks);
    println!("segmentation            chunks  mean-len  boundary-aligned");
    println!(
        "structure-aware        {:>6}  {:>8.1}  {:>15.1}%",
        sa_stats.count, sa_stats.mean_len, 100.0 * sa_stats.boundary_alignment
    );
    println!(
        "fixed-16 (Quest)       {:>6}  {:>8.1}  {:>15.1}%",
        fx_stats.count, fx_stats.mean_len, 100.0 * fx_stats.boundary_alignment
    );

    // --- what does each policy retrieve for the first probe? --------
    let mut cfg = LycheeConfig::default();
    cfg.budget = 512;
    cfg.sink = 8;
    cfg.recent = 32;
    let keys = FlatKeys::new(&task.keys, task.d);
    let n = task.n_tokens();
    let ctx = Ctx { keys: &keys, text: &task.text, n };
    let q = &task.queries[0];
    let target = &task.units[q.targets[0]];
    println!(
        "\nneedle record at [{}, {}): {:?}",
        target.start,
        target.end(),
        String::from_utf8_lossy(&task.text[target.start..target.end().min(target.start + 48)])
    );
    for name in ["quest", "clusterkv", "lychee"] {
        let mut p = make_policy(name, &cfg, 1, 4).unwrap();
        p.build(&ctx);
        let sel = p.select(&ctx, &q.q, n);
        let cov = task.unit_coverage(q.targets[0], &sel);
        println!(
            "{:<10} retrieved {:>3} tokens of the record ({:>5.1}% coverage) -> {}",
            name,
            (cov * target.len as f64) as usize,
            cov * 100.0,
            if cov >= q.coverage { "ANSWERABLE" } else { "fragmented" }
        );
    }

    // --- aggregate accuracy over all probes --------------------------
    println!("\naccuracy over {} probes:", task.queries.len());
    for name in ["quest", "clusterkv", "lychee", "full"] {
        let r = run_task(&task, name, &cfg, 1).expect("policy in registry");
        println!("  {:<10} {:>5.1}%  (recall {:.1}%)", name, r.accuracy * 100.0, r.recall * 100.0);
    }
}
