//! Quickstart: load the AOT artifacts, prefill a prompt, and stream a few
//! tokens through the LycheeCluster decode path.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use lychee::config::Config;
use lychee::engine::{Engine, Sampling};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::new();
    if !std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        cfg.artifacts_dir = "artifacts".into();
    }
    let engine = Engine::load(cfg)?;
    println!(
        "loaded LycheeLM: {} layers, d_model {}, platform {}",
        engine.dims().layers,
        engine.dims().d_model,
        engine.rt.platform()
    );

    let prompt = b"LycheeCluster organizes the KV cache into a pyramid: \
coarse units, fine clusters, and structure-aware chunks. ";
    let mut seq = engine.prefill(1, prompt, "lychee")?;
    println!("prefilled {} tokens", seq.pos);

    let sampling = Sampling::default();
    print!("generated: ");
    for _ in 0..24 {
        let tok = engine.decode_step(&mut seq, &sampling)?;
        print!("{}", String::from_utf8_lossy(&[tok]));
    }
    println!();

    println!("\nper-phase decode time:");
    for (phase, total_us, share) in seq.timer.breakdown() {
        println!("  {phase:<10} {:>8.2} ms  {:>5.1}%", total_us / 1e3, share * 100.0);
    }
    println!(
        "\nKV cache: {:.1} kB, retrieval index: {:.1} kB ({:.2}% overhead)",
        seq.kv_bytes() as f64 / 1e3,
        seq.index_bytes() as f64 / 1e3,
        100.0 * seq.index_bytes() as f64 / seq.kv_bytes() as f64
    );
    Ok(())
}
