"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for Rust (L3).

Emits HLO *text* (NOT `.serialize()`): jax >= 0.5 serializes HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` 0.1.6 crate links) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Outputs in --out (default ../artifacts):
  <program>.hlo.txt   one per (stage, shape-bucket) - see PROGRAMS below
  weights.bin         LCT1 tensor container with LycheeLM parameters
  manifest.json       program table (files, arg specs, output arity),
                      model config, weight order, bucket lists

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.chunk_pool import chunk_pool

CFG = M.CFG

# Shape buckets (the Rust runtime picks the smallest bucket that fits).
BATCH_BUCKETS = (1, 4, 8)
ATTN_M_B1 = (128, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536)
ATTN_M_BN = (128, 512, 1024, 2048)
PREFILL_S = (128, 512, 2048)
KVBUF_M = (2048, 16384, 65536, 131072)
GATHER_N = (1024, 2048)
POOL_SC = ((512, 128), (2048, 512))


def to_hlo_text(lowered, return_tuple: bool) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def spec_json(s):
    return {"dtype": str(s.dtype), "shape": list(s.shape)}


def build_programs():
    """Yield (name, fn, arg_specs, n_outputs)."""
    h, dh, d, f, v = CFG.heads, CFG.head_dim, CFG.d_model, CFG.ffn, CFG.vocab
    progs = []

    for b in BATCH_BUCKETS:
        progs.append((f"embed_b{b}", M.embed, [f32(v, d), i32(b)], 1))
        progs.append((
            f"qkv_b{b}", M.qkv,
            [f32(b, d), f32(d), f32(d, d), f32(d, d), f32(d, d), i32(b)], 3))
        progs.append((
            f"proj_ffn_b{b}", M.proj_ffn,
            [f32(b, h, dh), f32(b, d), f32(d, d), f32(d), f32(d, f), f32(f, d)],
            1))
        progs.append((f"lm_head_b{b}", M.lm_head, [f32(b, d), f32(d), f32(v, d)], 1))
        ms = ATTN_M_B1 if b == 1 else ATTN_M_BN
        for m in ms:
            progs.append((
                f"attn_b{b}_m{m}", M.attn,
                [f32(b, h, dh), f32(b, m, h, dh), f32(b, m, h, dh), f32(b, m)],
                1))

    n_params = len(M.param_order())
    for s in PREFILL_S:
        def prefill_fn(*args, _s=s):
            return M.prefill(args[:n_params], args[n_params], args[n_params + 1])
        specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in PARAM_SPECS]
        specs += [i32(s), i32()]
        progs.append((f"prefill_s{s}", prefill_fn, specs, 4))

    for mmax in KVBUF_M:
        progs.append((
            f"append_m{mmax}", M.append_kv,
            [f32(mmax, h, dh), f32(h, dh), i32()], 1))
        for n in GATHER_N:
            progs.append((
                f"gather_m{mmax}_n{n}", M.gather_kv,
                [f32(mmax, h, dh), i32(n)], 1))

    for s, c in POOL_SC:
        progs.append((f"pool_s{s}_c{c}", chunk_pool, [f32(s, d), i32(c), i32(c)], 1))

    return progs


def make_param_specs(params):
    return [jax.ShapeDtypeStruct(params[n].shape, params[n].dtype)
            for n in M.param_order()]


DTYPE_CODE = {"float32": 0, "int32": 1}


def write_lct1(path, named_arrays):
    """LCT1 tensor container: magic, count, then (name, dtype, dims, data)."""
    with open(path, "wb") as fh:
        fh.write(b"LCT1")
        fh.write(struct.pack("<I", len(named_arrays)))
        for name, arr in named_arrays:
            arr = np.ascontiguousarray(arr)
            nb = name.encode("utf-8")
            fh.write(struct.pack("<H", len(nb)))
            fh.write(nb)
            fh.write(struct.pack("<BB", DTYPE_CODE[str(arr.dtype)], arr.ndim))
            for dim in arr.shape:
                fh.write(struct.pack("<I", dim))
            fh.write(arr.tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated program-name prefixes to (re)build")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    global PARAM_SPECS
    params = M.init_params(jax.random.PRNGKey(0))
    PARAM_SPECS = make_param_specs(params)

    order = M.param_order()
    write_lct1(os.path.join(args.out, "weights.bin"),
               [(n, np.asarray(params[n])) for n in order])
    print(f"wrote weights.bin ({len(order)} tensors)")

    only = args.only.split(",") if args.only else None
    manifest_programs = {}
    t_all = time.time()
    for name, fn, specs, nouts in build_programs():
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        manifest_programs[name] = {
            "file": fname,
            "tuple": nouts > 1,
            "nouts": nouts,
            "args": [spec_json(s) for s in specs],
        }
        if only and not any(name.startswith(p) for p in only):
            continue
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered, return_tuple=nouts > 1)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"  {fname:28s} {len(text)/1e3:9.1f} kB  {time.time()-t0:5.1f}s",
              flush=True)

    manifest = {
        "model": {
            "vocab": CFG.vocab, "layers": CFG.layers, "heads": CFG.heads,
            "head_dim": CFG.head_dim, "d_model": CFG.d_model, "ffn": CFG.ffn,
            "rope_theta": CFG.rope_theta, "norm_eps": CFG.norm_eps,
            "layer_tensors": list(M.LAYER_TENSORS),
            "final_tensors": list(M.FINAL_TENSORS),
        },
        "weights": {"file": "weights.bin", "order": order},
        "buckets": {
            "batch": list(BATCH_BUCKETS),
            "attn_m_b1": list(ATTN_M_B1),
            "attn_m_bn": list(ATTN_M_BN),
            "prefill_s": list(PREFILL_S),
            "kvbuf_m": list(KVBUF_M),
            "gather_n": list(GATHER_N),
            "pool_sc": [list(x) for x in POOL_SC],
        },
        "programs": manifest_programs,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"wrote manifest.json ({len(manifest_programs)} programs, "
          f"{time.time()-t_all:.0f}s total)")


if __name__ == "__main__":
    sys.exit(main())
