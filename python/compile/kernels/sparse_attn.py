"""L1 Pallas kernel: budget-padded sparse attention (the decode hot-spot).

The Rust coordinator (L3) retrieves the active KV set for a decode step via
the hierarchical LycheeCluster index and gathers it into a dense,
budget-padded buffer ``k/v: [B, M, H, Dh]`` with a validity mask
``mask: [B, M]`` (1.0 = real token, 0.0 = padding). This kernel computes
exact multi-head attention of one query token per sequence over that
active set:

    out[b, h] = sum_i softmax_i(q[b,h] . k[b,i,h] / sqrt(Dh)) * v[b,i,h]

TPU adaptation of the paper's CUDA gathered-attention kernel (see
DESIGN.md "Hardware-Adaptation"): the grid iterates (batch, head) and the
M dimension is consumed in BM-sized blocks with an online-softmax
(running max / running sum) recurrence, i.e. the classic
flash-attention schedule expressed as an HBM->VMEM block pipeline. With
``interpret=True`` the same kernel lowers to plain HLO (a while loop over
blocks) so the Rust PJRT CPU client can execute it; on a real TPU the
block loop becomes the Mosaic grid over VMEM tiles feeding the MXU.

All-padding blocks are handled exactly: probabilities are multiplied by
the mask, so a fully-masked active set yields a zero output vector rather
than NaN.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size along the active-set dimension M. 128 keys x Dh=32 floats is
# 16 KiB per ref block - comfortably VMEM-resident alongside q/v/accum.
DEFAULT_BLOCK_M = 128


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, bm: int, nm: int,
                 scale: float):
    """One (batch, head) program: online-softmax over nm blocks of M."""
    q = q_ref[0, 0, :].astype(jnp.float32)  # [Dh]
    dh = q.shape[0]

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_blk = k_ref[0, pl.dslice(i * bm, bm), 0, :].astype(jnp.float32)
        v_blk = v_ref[0, pl.dslice(i * bm, bm), 0, :].astype(jnp.float32)
        msk = mask_ref[0, pl.dslice(i * bm, bm)].astype(jnp.float32)  # [bm]
        # Scores; padding positions are pushed to -inf *and* their
        # probability mass is zeroed below (robust to all-padding blocks).
        s = jnp.dot(k_blk, q) * scale + (msk - 1.0) * 1e30
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new) * msk
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p)
        acc_new = acc * alpha + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    init = (jnp.float32(-1e30), jnp.float32(0.0), jnp.zeros((dh,), jnp.float32))
    _, l_fin, acc = jax.lax.fori_loop(0, nm, body, init)
    safe_l = jnp.maximum(l_fin, 1e-30)
    o_ref[0, 0, :] = jnp.where(l_fin > 0.0, acc / safe_l, 0.0)


@functools.partial(jax.jit, static_argnames=("block_m",))
def sparse_attention(q, k, v, mask, *, block_m: int = DEFAULT_BLOCK_M):
    """Masked single-query multi-head attention over a padded active set.

    Args:
      q:    [B, H, Dh] query for the current decode position.
      k:    [B, M, H, Dh] gathered active keys (padded).
      v:    [B, M, H, Dh] gathered active values (padded).
      mask: [B, M] 1.0 for valid tokens, 0.0 for padding.

    Returns:
      [B, H, Dh] attention output (zeros where the active set is empty).
    """
    b, h, dh = q.shape
    m = k.shape[1]
    assert k.shape == (b, m, h, dh), (k.shape, (b, m, h, dh))
    assert v.shape == k.shape
    assert mask.shape == (b, m)
    bm = min(block_m, m)
    assert m % bm == 0, f"M={m} must be a multiple of block_m={bm}"
    nm = m // bm
    scale = 1.0 / float(dh) ** 0.5

    kernel = functools.partial(_attn_kernel, bm=bm, nm=nm, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda bi, hi: (bi, hi, 0)),
            pl.BlockSpec((1, m, 1, dh), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, m, 1, dh), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, m), lambda bi, hi: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda bi, hi: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=True,  # CPU PJRT target; Mosaic custom-calls cannot run here.
    )(q, k, v, mask)
