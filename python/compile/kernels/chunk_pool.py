"""L1 Pallas kernel: variable-length chunk mean-pooling (index build).

LycheeCluster's index construction computes, for every structure-aware
chunk, a representative key: the mean of the chunk's (head-merged) token
keys followed by L2 normalization (paper section 4.3). The paper ships a
CUDA "variable-length chunk parallel pooling" kernel; this is the TPU
adaptation (DESIGN.md "Hardware-Adaptation"):

- chunks are contiguous token spans with length <= WMAX (the chunker's
  max-chunk bound, 16 by default), so instead of a segmented atomic
  reduction each grid program loads one fixed WMAX-token window starting
  at the chunk offset and masks the tail - no atomics, MXU-free VPU
  reduction, one pass over the keys.
- a chunk starting closer than WMAX to the end of the buffer would make
  the dynamic slice clamp and shift; the kernel compensates by clamping
  the window start and offsetting the validity mask.

Inputs:
  keys   [S, D]  head-merged token keys for one layer.
  starts [C] int32 chunk start offsets (padded entries: any value).
  lens   [C] int32 chunk lengths (0 for padded entries).

Output:
  pooled [C, D]  L2-normalized mean key per chunk (zeros for len==0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Upper bound on chunk length; must match the Rust chunker's max_chunk.
DEFAULT_WMAX = 16


def _pool_kernel(starts_ref, lens_ref, keys_ref, out_ref, *, wmax: int,
                 s_total: int):
    c = pl.program_id(0)
    start = starts_ref[c]
    ln = lens_ref[c]
    # Clamp the window so the dynamic slice never shifts silently, then
    # offset the in-window validity range accordingly.
    start_c = jnp.minimum(start, jnp.int32(max(s_total - wmax, 0)))
    off = start - start_c
    window = pl.load(keys_ref, (pl.dslice(start_c, wmax), slice(None)))
    idx = jax.lax.iota(jnp.int32, wmax)
    valid = jnp.logical_and(idx >= off, idx < off + ln)
    w = valid.astype(jnp.float32)[:, None]
    total = jnp.sum(window.astype(jnp.float32) * w, axis=0)
    mean = total / jnp.maximum(ln.astype(jnp.float32), 1.0)
    norm = jnp.sqrt(jnp.sum(mean * mean))
    unit = mean / jnp.maximum(norm, 1e-12)
    out_ref[0, :] = jnp.where(ln > 0, unit, 0.0)


@functools.partial(jax.jit, static_argnames=("wmax",))
def chunk_pool(keys, starts, lens, *, wmax: int = DEFAULT_WMAX):
    """Mean-pool + L2-normalize contiguous chunk spans of `keys`."""
    s_total, d = keys.shape
    (c,) = starts.shape
    assert lens.shape == (c,)

    kernel = functools.partial(_pool_kernel, wmax=wmax, s_total=s_total)
    return pl.pallas_call(
        kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((c,), lambda ci: (0,)),
            pl.BlockSpec((c,), lambda ci: (0,)),
            pl.BlockSpec((s_total, d), lambda ci: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda ci: (ci, 0)),
        out_shape=jax.ShapeDtypeStruct((c, d), jnp.float32),
        interpret=True,  # CPU PJRT target.
    )(starts, lens, keys)
