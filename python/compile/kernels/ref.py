"""Pure-jnp oracles for the Pallas kernels (build-time correctness only).

Every kernel in this package has an exact reference here; pytest asserts
allclose between kernel and oracle across shape/mask sweeps (hypothesis).
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_sparse_attention(q, k, v, mask):
    """Reference for kernels.sparse_attn.sparse_attention.

    q [B,H,Dh], k/v [B,M,H,Dh], mask [B,M] -> [B,H,Dh].
    Fully-masked rows return zeros (matching the kernel contract).
    """
    b, h, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    # [B,H,M]
    s = jnp.einsum("bhd,bmhd->bhm", q, k).astype(jnp.float32) * scale
    neg = (1.0 - mask.astype(jnp.float32))[:, None, :] * 1e30
    s = s - neg
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * mask.astype(jnp.float32)[:, None, :]
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhm,bmhd->bhd", p, v.astype(jnp.float32))
    any_valid = (jnp.sum(mask, axis=-1) > 0)[:, None, None]
    return jnp.where(any_valid, out / jnp.maximum(l, 1e-30), 0.0)


def ref_chunk_pool(keys, starts, lens):
    """Reference for kernels.chunk_pool.chunk_pool.

    keys [S,D], starts/lens [C] -> pooled [C,D] (L2-normalized means,
    zeros for empty chunks).
    """
    s_total, d = keys.shape
    idx = jnp.arange(s_total)[None, :]  # [1,S]
    lo = starts[:, None]
    hi = (starts + lens)[:, None]
    sel = ((idx >= lo) & (idx < hi)).astype(jnp.float32)  # [C,S]
    total = sel @ keys.astype(jnp.float32)  # [C,D]
    mean = total / jnp.maximum(lens.astype(jnp.float32), 1.0)[:, None]
    norm = jnp.linalg.norm(mean, axis=-1, keepdims=True)
    unit = mean / jnp.maximum(norm, 1e-12)
    return jnp.where((lens > 0)[:, None], unit, 0.0)
