"""L2: LycheeLM - the JAX model whose decode step the Rust engine drives.

A deliberately small byte-level decoder-only transformer (DESIGN.md
"Model"). The decode step is *split into per-stage functions* so the Rust
coordinator can run LycheeCluster retrieval between QKV and attention:

    embed -> [ qkv -> (L3 retrieval) -> sparse_attention -> proj_ffn ] x L
          -> lm_head

Every stage is AOT-lowered to HLO text by aot.py; weights are runtime
arguments (kept out of the HLO) written to artifacts/weights.bin.

Conventions:
  B  batch of decode-step tokens, S prompt length, V vocab (256 bytes)
  L  layers, H heads, Dh head dim, D = H*Dh model dim, F ffn dim
  KV layout is token-major [.., M/S, H, Dh] to match the Rust cache.
  RoPE is applied to both q and k *before* caching, so gathered keys are
  position-consistent without re-rotation (the Quest/ClusterKV convention).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from compile.kernels.sparse_attn import sparse_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    layers: int = 4
    heads: int = 4
    head_dim: int = 32
    ffn: int = 512
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def d_model(self) -> int:
        return self.heads * self.head_dim


CFG = ModelConfig()

# Per-layer tensors in canonical order (mirrored by the Rust weights loader).
LAYER_TENSORS = ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2")
FINAL_TENSORS = ("ln_f", "emb")


def init_params(key, cfg: ModelConfig = CFG):
    """Deterministic scaled-gaussian init; returns name -> array dict."""
    d, f, v = cfg.d_model, cfg.ffn, cfg.vocab
    params = {}
    key, ek = jax.random.split(key)
    params["emb"] = (jax.random.normal(ek, (v, d)) * 0.02).astype(jnp.float32)
    params["ln_f"] = jnp.ones((d,), jnp.float32)
    for l in range(cfg.layers):
        key, *ks = jax.random.split(key, 6)
        sd_attn = (2.0 / (d + d)) ** 0.5
        sd_f1 = (2.0 / (d + f)) ** 0.5
        params[f"l{l}.ln1"] = jnp.ones((d,), jnp.float32)
        params[f"l{l}.wq"] = (jax.random.normal(ks[0], (d, d)) * sd_attn).astype(jnp.float32)
        params[f"l{l}.wk"] = (jax.random.normal(ks[1], (d, d)) * sd_attn).astype(jnp.float32)
        params[f"l{l}.wv"] = (jax.random.normal(ks[2], (d, d)) * sd_attn).astype(jnp.float32)
        params[f"l{l}.wo"] = (jax.random.normal(ks[3], (d, d)) * sd_attn / (2 * cfg.layers) ** 0.5).astype(jnp.float32)
        params[f"l{l}.ln2"] = jnp.ones((d,), jnp.float32)
        params[f"l{l}.w1"] = (jax.random.normal(ks[4], (d, f)) * sd_f1).astype(jnp.float32)
        key, k2 = jax.random.split(key)
        params[f"l{l}.w2"] = (jax.random.normal(k2, (f, d)) * sd_f1 / (2 * cfg.layers) ** 0.5).astype(jnp.float32)
    return params


def param_order(cfg: ModelConfig = CFG):
    """Flat tensor order used by weights.bin and prefill's argument list."""
    names = []
    for l in range(cfg.layers):
        names.extend(f"l{l}.{t}" for t in LAYER_TENSORS)
    names.extend(FINAL_TENSORS)
    return names


# ---------------------------------------------------------------------------
# Numerics shared by stages
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = CFG.norm_eps):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope(x, pos, theta: float = CFG.rope_theta):
    """Rotate-half RoPE. x [..., H, Dh], pos int32 [...] (one per row)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None, None] * freqs  # [..., 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Decode-step stages (each one becomes an HLO artifact)
# ---------------------------------------------------------------------------

def embed(emb, tokens):
    """(emb [V,D], tokens i32[B]) -> x [B,D]."""
    return jnp.take(emb, tokens, axis=0)


def qkv(x, ln1, wq, wk, wv, pos, cfg: ModelConfig = CFG):
    """One layer's pre-attention: RMSNorm + QKV projections + RoPE.

    (x [B,D], ln1 [D], wq/wk/wv [D,D], pos i32[B]) -> q,k,v [B,H,Dh].
    k/v are what the Rust engine appends to the paged KV cache.
    """
    b = x.shape[0]
    h, dh = cfg.heads, cfg.head_dim
    xn = rms_norm(x, ln1)
    q = (xn @ wq).reshape(b, h, dh)
    k = (xn @ wk).reshape(b, h, dh)
    v = (xn @ wv).reshape(b, h, dh)
    return rope(q, pos), rope(k, pos), v


def attn(q, k, v, mask):
    """The L1 Pallas kernel, lowered into this stage's HLO."""
    return sparse_attention(q, k, v, mask)


def proj_ffn(attn_out, x_resid, wo, ln2, w1, w2):
    """Post-attention: output proj + residual + FFN + residual.

    (attn_out [B,H,Dh], x_resid [B,D], wo [D,D], ln2 [D], w1 [D,F],
     w2 [F,D]) -> x [B,D].
    """
    b = attn_out.shape[0]
    x1 = x_resid + attn_out.reshape(b, -1) @ wo
    hidden = jax.nn.gelu(rms_norm(x1, ln2) @ w1)
    return x1 + hidden @ w2


def lm_head(x, ln_f, emb):
    """(x [B,D], ln_f [D], emb [V,D]) -> logits [B,V] (tied embeddings)."""
    return rms_norm(x, ln_f) @ emb.T


# ---------------------------------------------------------------------------
# KV-cache device programs (keep KV device-resident on the Rust side)
# ---------------------------------------------------------------------------

def append_kv(buf, new, pos):
    """(buf [Mmax,H,Dh], new [H,Dh], pos i32) -> buf with row pos replaced."""
    return jax.lax.dynamic_update_slice(buf, new[None], (pos, 0, 0))


def gather_kv(buf, idx):
    """(buf [Mmax,H,Dh], idx i32[M]) -> gathered [M,H,Dh].

    Device-side gather of the retrieved active set; the Rust engine only
    uploads the M int32 indices, never KV bytes (perf-critical).
    """
    return jnp.take(buf, idx, axis=0)


# ---------------------------------------------------------------------------
# Prefill (full causal attention; the paper does not accelerate prefill)
# ---------------------------------------------------------------------------

def prefill(flat_params, tokens, length, cfg: ModelConfig = CFG):
    """Process a (padded) prompt, producing the KV cache and next logits.

    Args:
      flat_params: tensors in param_order(cfg).
      tokens: i32[S] prompt, padded to the bucket size.
      length: i32 scalar, number of valid tokens (1..S).

    Returns:
      (k_cache [L,S,H,Dh], v_cache [L,S,H,Dh], x_last [D], logits [V])
    """
    named = dict(zip(param_order(cfg), flat_params))
    s = tokens.shape[0]
    h, dh = cfg.heads, cfg.head_dim
    scale = 1.0 / float(dh) ** 0.5
    pos = jnp.arange(s, dtype=jnp.int32)
    valid = pos < length

    x = jnp.take(named["emb"], tokens, axis=0)  # [S,D]
    ks, vs = [], []
    causal = pos[None, :] <= pos[:, None]  # [S(q),S(k)]
    attn_mask = causal & valid[None, :]
    for l in range(cfg.layers):
        p = lambda t: named[f"l{l}.{t}"]  # noqa: B023
        xn = rms_norm(x, p("ln1"))
        q = rope((xn @ p("wq")).reshape(s, h, dh), pos)
        k = rope((xn @ p("wk")).reshape(s, h, dh), pos)
        v = (xn @ p("wv")).reshape(s, h, dh)
        scores = jnp.einsum("qhd,khd->hqk", q, k) * scale
        scores = jnp.where(attn_mask[None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("hqk,khd->qhd", probs, v).reshape(s, -1)
        x = x + o @ p("wo")
        hidden = jax.nn.gelu(rms_norm(x, p("ln2")) @ p("w1"))
        x = x + hidden @ p("w2")
        ks.append(k)
        vs.append(v)
    k_cache = jnp.stack(ks)  # [L,S,H,Dh]
    v_cache = jnp.stack(vs)
    x_last = jnp.take(x, length - 1, axis=0)  # [D]
    logits = rms_norm(x_last, named["ln_f"]) @ named["emb"].T
    return k_cache, v_cache, x_last, logits


# ---------------------------------------------------------------------------
# Reference full decode step (used by tests to validate stage composition)
# ---------------------------------------------------------------------------

def decode_step_reference(params, token, position, k_cache, v_cache, n_valid,
                          cfg: ModelConfig = CFG):
    """Full-attention decode step composed from the stage functions.

    k_cache/v_cache: [L, Mmax, H, Dh] with rows [0, n_valid) valid.
    Returns (logits [V], new_k [L,H,Dh], new_v [L,H,Dh]).
    """
    mmax = k_cache.shape[1]
    x = embed(params["emb"], token[None])  # [1,D]
    pos = position[None]
    new_ks, new_vs = [], []
    for l in range(cfg.layers):
        p = lambda t: params[f"l{l}.{t}"]  # noqa: B023
        q, k, v = qkv(x, p("ln1"), p("wq"), p("wk"), p("wv"), pos, cfg)
        kc = jax.lax.dynamic_update_slice(k_cache[l], k, (n_valid, 0, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[l], v, (n_valid, 0, 0))
        mask = (jnp.arange(mmax) <= n_valid).astype(jnp.float32)[None]
        o = attn(q, kc[None], vc[None], mask)
        x = proj_ffn(o, x, p("wo"), p("ln2"), p("w1"), p("w2"))
        new_ks.append(k[0])
        new_vs.append(v[0])
    logits = lm_head(x, params["ln_f"], params["emb"])[0]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)
