"""Pallas chunk-pool kernel vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.chunk_pool import chunk_pool
from compile.kernels.ref import ref_chunk_pool


def make_chunks(rng, s, c, wmax=16):
    """Contiguous non-overlapping spans like the Rust chunker emits."""
    starts = np.zeros(c, np.int32)
    lens = np.zeros(c, np.int32)
    cur = 0
    for i in range(c):
        if cur >= s:
            break
        ln = int(rng.integers(1, wmax + 1))
        ln = min(ln, s - cur)
        starts[i], lens[i] = cur, ln
        cur += ln
    return jnp.asarray(starts), jnp.asarray(lens)


def check(keys, starts, lens):
    out = chunk_pool(keys, starts, lens)
    ref = ref_chunk_pool(keys, starts, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.sampled_from([64, 128, 512]),
    c=st.sampled_from([8, 32, 128]),
    d=st.sampled_from([16, 64, 128]),
)
def test_hypothesis_sweep(seed, s, c, d):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.normal(size=(s, d)), jnp.float32)
    starts, lens = make_chunks(rng, s, c)
    check(keys, starts, lens)


def test_output_is_unit_norm():
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.normal(size=(128, 32)), jnp.float32)
    starts, lens = make_chunks(rng, 128, 16)
    out = np.asarray(chunk_pool(keys, starts, lens))
    norms = np.linalg.norm(out, axis=-1)
    valid = np.asarray(lens) > 0
    np.testing.assert_allclose(norms[valid], 1.0, rtol=1e-5)
    assert np.all(out[~valid] == 0.0)


def test_tail_chunk_near_buffer_end():
    """A chunk within WMAX of the end must not be shifted by slice clamping."""
    rng = np.random.default_rng(1)
    keys = jnp.asarray(rng.normal(size=(128, 16)), jnp.float32)
    starts = jnp.asarray(np.array([123], np.int32))
    lens = jnp.asarray(np.array([5], np.int32))
    check(keys, starts, lens)


def test_single_token_chunk_is_normalized_key():
    rng = np.random.default_rng(2)
    keys = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    starts = jnp.asarray(np.array([10], np.int32))
    lens = jnp.asarray(np.array([1], np.int32))
    out = np.asarray(chunk_pool(keys, starts, lens))[0]
    k = np.asarray(keys)[10]
    np.testing.assert_allclose(out, k / np.linalg.norm(k), rtol=1e-5)


@pytest.mark.parametrize("wmax", [4, 8, 16])
def test_wmax_variants(wmax):
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.normal(size=(256, 32)), jnp.float32)
    starts, lens = make_chunks(rng, 256, 32, wmax=wmax)
    out = chunk_pool(keys, starts, lens, wmax=wmax)
    ref = ref_chunk_pool(keys, starts, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
