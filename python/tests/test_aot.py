"""AOT pipeline tests: HLO-text emission and the LCT1 weights container."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_emits_parseable_module():
    lowered = jax.jit(lambda x, y: x @ y + 1.0).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered, return_tuple=False)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_to_hlo_text_tuple_root():
    lowered = jax.jit(lambda x: (x + 1.0, x * 2.0)).lower(
        jax.ShapeDtypeStruct((2,), jnp.float32))
    text = aot.to_hlo_text(lowered, return_tuple=True)
    assert "tuple" in text.lower()


def read_lct1(path):
    out = {}
    with open(path, "rb") as fh:
        assert fh.read(4) == b"LCT1"
        (count,) = struct.unpack("<I", fh.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", fh.read(2))
            name = fh.read(nlen).decode()
            dt, nd = struct.unpack("<BB", fh.read(2))
            dims = struct.unpack(f"<{nd}I", fh.read(4 * nd))
            dtype = np.float32 if dt == 0 else np.int32
            n = int(np.prod(dims)) if nd else 1
            data = np.frombuffer(fh.read(4 * n), dtype=dtype).reshape(dims)
            out[name] = data
    return out


def test_lct1_round_trip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = [
        ("a", rng.normal(size=(3, 4)).astype(np.float32)),
        ("b.ln", np.arange(7, dtype=np.float32)),
        ("c_idx", np.array([[1, 2], [3, 4]], np.int32)),
    ]
    path = tmp_path / "w.bin"
    aot.write_lct1(path, tensors)
    back = read_lct1(path)
    assert list(back.keys()) == ["a", "b.ln", "c_idx"]
    for name, arr in tensors:
        np.testing.assert_array_equal(back[name], arr)


def test_build_programs_covers_all_stages():
    aot.PARAM_SPECS = aot.make_param_specs(M.init_params(jax.random.PRNGKey(0)))
    progs = {name for name, *_ in aot.build_programs()}
    for b in aot.BATCH_BUCKETS:
        for stem in ("embed", "qkv", "proj_ffn", "lm_head"):
            assert f"{stem}_b{b}" in progs
    for m in aot.ATTN_M_B1:
        assert f"attn_b1_m{m}" in progs
    for s in aot.PREFILL_S:
        assert f"prefill_s{s}" in progs
    for mm in aot.KVBUF_M:
        assert f"append_m{mm}" in progs


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="run `make artifacts` first")
def test_manifest_programs_exist_on_disk():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as fh:
        manifest = json.load(fh)
    assert manifest["model"]["d_model"] == M.CFG.d_model
    for name, meta in manifest["programs"].items():
        path = os.path.join(ARTIFACTS, meta["file"])
        assert os.path.exists(path), f"missing artifact {name}"
        with open(path) as fh:
            head = fh.read(256)
        assert "HloModule" in head


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "weights.bin")),
                    reason="run `make artifacts` first")
def test_weights_bin_matches_param_order():
    back = read_lct1(os.path.join(ARTIFACTS, "weights.bin"))
    assert list(back.keys()) == M.param_order()
    params = M.init_params(jax.random.PRNGKey(0))
    for n in M.param_order():
        np.testing.assert_allclose(back[n], np.asarray(params[n]), rtol=1e-6)
