"""L2 model correctness: stage composition == monolithic prefill.

The critical invariant for the Rust engine: running the *per-stage*
decode-step programs (embed/qkv/attn/proj_ffn/lm_head) with a
full-attention active set must reproduce the logits that the monolithic
prefill program computes - i.e. the stage split introduces no numerical
divergence beyond float tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.CFG


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0))


def test_param_order_matches_dict(params):
    order = M.param_order()
    assert len(order) == CFG.layers * len(M.LAYER_TENSORS) + len(M.FINAL_TENSORS)
    assert set(order) == set(params.keys())


def test_rope_preserves_norm():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, CFG.heads, CFG.head_dim)), jnp.float32)
    pos = jnp.asarray([0, 1, 77, 4096], jnp.int32)
    y = M.rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 1, CFG.head_dim)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, CFG.head_dim)), jnp.float32)

    def dot(i, j):
        qi = M.rope(q, jnp.asarray([i], jnp.int32))
        kj = M.rope(k, jnp.asarray([j], jnp.int32))
        return float(jnp.sum(qi * kj))

    assert abs(dot(5, 3) - dot(105, 103)) < 1e-3
    assert abs(dot(17, 0) - dot(1017, 1000)) < 1e-3


def test_rope_position_zero_is_identity():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 2, CFG.head_dim)), jnp.float32)
    y = M.rope(x, jnp.asarray([0], jnp.int32))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)


def test_rms_norm_scale_invariant_direction():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    w = jnp.ones((8,), jnp.float32)
    a, b = M.rms_norm(x, w), M.rms_norm(3.0 * x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4)


def _run_prefill(params, tokens, length, s_bucket):
    padded = np.zeros(s_bucket, np.int32)
    padded[: len(tokens)] = tokens
    flat = [params[n] for n in M.param_order()]
    return M.prefill(flat, jnp.asarray(padded), jnp.int32(length))


def test_prefill_padding_invariance(params):
    """Prefill result must not depend on bucket padding."""
    rng = np.random.default_rng(4)
    toks = rng.integers(0, 256, size=20).astype(np.int32)
    k1, v1, x1, lg1 = _run_prefill(params, toks, 20, 32)
    k2, v2, x2, lg2 = _run_prefill(params, toks, 20, 64)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(k1)[:, :20], np.asarray(k2)[:, :20],
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), atol=1e-4)


def test_stage_composition_matches_prefill(params):
    """Decode token t via stages (full active set) == prefill at length t+1."""
    rng = np.random.default_rng(5)
    n = 24
    toks = rng.integers(0, 256, size=n).astype(np.int32)
    s_bucket = 32
    mmax = 64

    # Ground truth: prefill over the first t tokens gives logits for token t.
    k_pre, v_pre, _, logits_pre = _run_prefill(params, toks, n, s_bucket)

    # Stage path: prefill first n-1 tokens, then decode token n-1 by stages.
    k_c, v_c, _, _ = _run_prefill(params, toks, n - 1, s_bucket)
    k_cache = np.zeros((CFG.layers, mmax, CFG.heads, CFG.head_dim), np.float32)
    v_cache = np.zeros_like(k_cache)
    k_cache[:, : n - 1] = np.asarray(k_c)[:, : n - 1]
    v_cache[:, : n - 1] = np.asarray(v_c)[:, : n - 1]

    logits, new_k, new_v = M.decode_step_reference(
        params, jnp.asarray(toks[n - 1]), jnp.int32(n - 1),
        jnp.asarray(k_cache), jnp.asarray(v_cache), jnp.int32(n - 1))

    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_pre),
                               rtol=1e-3, atol=1e-3)
    # The k/v the decode step produces must match prefill's row n-1.
    np.testing.assert_allclose(np.asarray(new_k), np.asarray(k_pre)[:, n - 1],
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(new_v), np.asarray(v_pre)[:, n - 1],
                               rtol=1e-3, atol=1e-4)


def test_multi_step_stage_decode_matches_prefill(params):
    """Greedy 6-step stage decode == prefill-recomputed logits each step."""
    rng = np.random.default_rng(6)
    n0, steps = 12, 6
    toks = list(rng.integers(0, 256, size=n0).astype(np.int32))
    mmax = 64
    k_c, v_c, _, logits = _run_prefill(params, np.asarray(toks), n0, 32)
    k_cache = np.zeros((CFG.layers, mmax, CFG.heads, CFG.head_dim), np.float32)
    v_cache = np.zeros_like(k_cache)
    k_cache[:, :n0] = np.asarray(k_c)[:, :n0]
    v_cache[:, :n0] = np.asarray(v_c)[:, :n0]

    cur = int(np.argmax(np.asarray(logits)))
    for t in range(steps):
        pos = n0 + t
        logits_s, nk, nv = M.decode_step_reference(
            params, jnp.asarray(cur, jnp.int32), jnp.int32(pos),
            jnp.asarray(k_cache), jnp.asarray(v_cache), jnp.int32(pos))
        k_cache[:, pos] = np.asarray(nk)
        v_cache[:, pos] = np.asarray(nv)
        toks.append(cur)
        # oracle: full prefill over toks (length pos+1) gives same logits
        _, _, _, logits_o = _run_prefill(params, np.asarray(toks), pos + 1, 32)
        np.testing.assert_allclose(np.asarray(logits_s), np.asarray(logits_o),
                                   rtol=2e-3, atol=2e-3)
        cur = int(np.argmax(np.asarray(logits_s)))


def test_qkv_rope_consistency(params):
    """qkv() applies RoPE at the given positions (cache convention)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(2, CFG.d_model)), jnp.float32)
    p = lambda t: params[f"l0.{t}"]
    q0, k0, _ = M.qkv(x, p("ln1"), p("wq"), p("wk"), p("wv"),
                      jnp.asarray([0, 0], jnp.int32))
    q5, k5, _ = M.qkv(x, p("ln1"), p("wq"), p("wk"), p("wv"),
                      jnp.asarray([5, 9], jnp.int32))
    expect_q5 = M.rope(q0, jnp.asarray([5, 9], jnp.int32))
    np.testing.assert_allclose(np.asarray(q5), np.asarray(expect_q5),
                               rtol=1e-4, atol=1e-5)
    assert not np.allclose(np.asarray(k0), np.asarray(k5))
