"""Pallas sparse-attention kernel vs pure-jnp oracle (CORE correctness).

Hypothesis sweeps shapes and mask patterns; explicit cases cover the
edge conditions the Rust engine relies on (all-padding, single token,
block-boundary M values).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ref_sparse_attention
from compile.kernels.sparse_attn import sparse_attention

RTOL, ATOL = 1e-5, 1e-5


def rand_case(seed, b, m, h, dh, mask_kind):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, m, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, m, h, dh)), jnp.float32)
    if mask_kind == "full":
        mask = np.ones((b, m), np.float32)
    elif mask_kind == "none":
        mask = np.zeros((b, m), np.float32)
    elif mask_kind == "prefix":
        mask = np.zeros((b, m), np.float32)
        for i in range(b):
            mask[i, : rng.integers(1, m + 1)] = 1.0
    else:  # random
        mask = rng.integers(0, 2, size=(b, m)).astype(np.float32)
    return q, k, v, jnp.asarray(mask)


def check(q, k, v, mask, block_m=128):
    out = sparse_attention(q, k, v, mask, block_m=block_m)
    ref = ref_sparse_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.sampled_from([1, 2, 4, 8]),
    m=st.sampled_from([128, 256, 512, 1024]),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32]),
    mask_kind=st.sampled_from(["full", "none", "prefix", "random"]),
)
def test_hypothesis_sweep(seed, b, m, h, dh, mask_kind):
    check(*rand_case(seed, b, m, h, dh, mask_kind))


def test_all_padding_returns_zeros():
    q, k, v, mask = rand_case(0, 2, 128, 4, 32, "none")
    out = np.asarray(sparse_attention(q, k, v, mask))
    assert np.all(out == 0.0)


def test_single_valid_token_returns_its_value():
    q, k, v, _ = rand_case(1, 1, 128, 4, 32, "full")
    mask = np.zeros((1, 128), np.float32)
    mask[0, 37] = 1.0
    out = np.asarray(sparse_attention(q, k, v, jnp.asarray(mask)))
    np.testing.assert_allclose(out[0], np.asarray(v)[0, 37], rtol=1e-6)


@pytest.mark.parametrize("m", [128, 256, 1024, 2048])
def test_block_boundaries(m):
    check(*rand_case(7, 1, m, 4, 32, "random"))


@pytest.mark.parametrize("block_m", [32, 64, 128])
def test_block_size_invariance(block_m):
    q, k, v, mask = rand_case(3, 2, 256, 2, 16, "random")
    check(q, k, v, mask, block_m=block_m)


def test_matches_softmax_definition():
    """Independent from ref.py: direct softmax computation."""
    q, k, v, mask = rand_case(11, 1, 128, 1, 8, "prefix")
    out = np.asarray(sparse_attention(q, k, v, mask))[0, 0]
    qn, kn, vn, mn = (np.asarray(a, np.float64) for a in (q, k, v, mask))
    s = kn[0, :, 0, :] @ qn[0, 0] / np.sqrt(8.0)
    s[mn[0] == 0] = -np.inf
    p = np.exp(s - s.max())
    p /= p.sum()
    np.testing.assert_allclose(out, p @ vn[0, :, 0, :], rtol=1e-4, atol=1e-5)


def test_scale_applied():
    """Doubling Dh must change scaling (guards 1/sqrt(dh) regressions)."""
    q, k, v, mask = rand_case(5, 1, 128, 1, 16, "full")
    out16 = sparse_attention(q, k, v, mask)
    # identical inputs zero-padded to dh=32 -> same dots, different scale
    pad = lambda a: jnp.concatenate([a, jnp.zeros_like(a)], axis=-1)
    out32 = sparse_attention(pad(q), pad(k), pad(v), mask)
    assert not np.allclose(np.asarray(out16), np.asarray(out32)[..., :16])
